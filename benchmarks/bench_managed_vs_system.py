"""Paper Fig. 4: managed (page-migrating) vs system (fine-grained) memory.

TPU adaptation: resident-after-migration vs per-touch streaming of a
host-placed buffer (DESIGN.md §2.1).  Measured: a compute loop touching a
buffer k times, either migrated to device once or re-fetched from
pinned_host every touch — the crossover in k reproduces the figure's
shape.  Analytic: the closed-form crossover from the datapath model."""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import SingleDeviceSharding

from benchmarks.common import emit
from repro.core import MemoryTier, migration_crossover_touches, streaming_time
from repro.core.membench import measure


def main() -> None:
    dev = jax.devices()[0]
    kinds = {m.kind for m in dev.addressable_memories()}
    nbytes = 2**25  # 32 MiB
    x_host = jax.device_put(
        jnp.ones((nbytes // 4,), jnp.float32),
        SingleDeviceSharding(
            dev, memory_kind="pinned_host" if "pinned_host" in kinds else "device"
        ),
    )
    dev_sharding = SingleDeviceSharding(dev, memory_kind="device")

    def to_dev(v):
        return jax.device_put(v, dev_sharding)
    touch = jax.jit(lambda v: jnp.sum(v * 1.0001))

    for k in (1, 4, 16, 64):
        def migrated(k=k):
            v = to_dev(x_host)           # one bulk migration
            acc = 0.0
            for _ in range(k):
                acc = acc + touch(v)
            return acc

        def streamed(k=k):
            acc = 0.0
            for _ in range(k):
                acc = acc + touch(to_dev(x_host))  # re-fetch per touch
            return acc

        m1 = measure(migrated, name=f"migrated[k={k}]", repeats=3)
        m2 = measure(streamed, name=f"streamed[k={k}]", repeats=3)
        emit(m1.name, m1.us_per_call, f"{nbytes*k/m1.mean_s/1e9:.2f}GB/s-effective")
        emit(m2.name, m2.us_per_call, f"{nbytes*k/m2.mean_s/1e9:.2f}GB/s-effective")

    # analytic crossover (the paper's "~128 iterations" point, for TPU)
    x = migration_crossover_touches(MemoryTier.HOST)
    emit("analytic_crossover[host]", 0.0, f"{x:.1f}touches")
    for k in (1, 4, 16, 64, 256):
        t_stream = streaming_time(2**30, MemoryTier.HOST, touches=k)
        t_mig = streaming_time(2**30, MemoryTier.HBM, touches=k) + streaming_time(
            2**30, MemoryTier.HOST, touches=1
        )
        winner = "migrate" if t_mig < t_stream else "stream"
        emit(f"analytic_managed[k={k}]", min(t_mig, t_stream) * 1e6, winner)


if __name__ == "__main__":
    main()
