"""Shared benchmark infra: CSV emission + subprocess multi-device runner.

Every benchmark prints ``name,us_per_call,derived`` rows (one per measured
or derived point).  Measured rows run on the available devices (CPU here);
``analytic`` rows evaluate the TPU datapath model — the two modes the
hardware-adaptation note in DESIGN.md §2.1 prescribes.
"""

from __future__ import annotations

import os
import subprocess
import sys

SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")


def emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.2f},{derived}")


def emit_measurement(m, derived: str | None = None) -> None:
    print(m.csv(derived))


def run_with_devices(code: str, n: int = 8, timeout: int = 600) -> str:
    """Run a snippet under n forced host devices; returns stdout.

    Used by the collective/pingpong benches — the main process must keep
    seeing 1 device (task requirement), so multi-device measurement always
    happens in a child process.
    """
    script = (
        "import os\n"
        f'os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n}"\n'
        + code
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    if r.returncode != 0:
        raise RuntimeError(f"subprocess failed:\n{r.stderr[-2000:]}")
    return r.stdout
