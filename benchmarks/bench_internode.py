"""Paper Fig. 14: internode bandwidth scaling with message size and the
number of injection streams.

Alps: one NIC per GH200, 4 per node — full node bandwidth needs 4 MPI
processes.  TPU analogue: per-chip DCN injection; a pod's inter-pod
bandwidth scales with how many chips participate in the cross-pod
collective.  Measured: psum over the 'pod' axis of a (2,4) host-device
mesh in a subprocess.  Analytic: alpha-beta model over message size for
1/2/4 streams."""

from __future__ import annotations

from benchmarks.common import emit, run_with_devices
from repro.core import Link, get_active_system

CODE = """
import jax, jax.numpy as jnp, time
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.mesh import make_mesh_compat
mesh = make_mesh_compat((2, 4), ("pod", "data"))
for log2 in (16, 20, 24):
    n = 2 ** log2 // 4
    x = jax.device_put(jnp.ones((n,), jnp.float32),
                       NamedSharding(mesh, P()))
    f = jax.jit(lambda v: v * 2, donate_argnums=0)  # warm baseline
    # cross-pod all-reduce via psum under shard_map
    from jax.experimental.shard_map import shard_map
    g = jax.jit(shard_map(lambda v: jax.lax.psum(v, "pod"), mesh=mesh,
                          in_specs=P(None), out_specs=P(None),
                          check_rep=False))
    out = g(x); jax.block_until_ready(out)
    reps = 10
    t0 = time.perf_counter()
    for _ in range(reps):
        out = g(x)
    jax.block_until_ready(out)
    dt = (time.perf_counter() - t0) / reps
    gbps = (n * 4) / dt / 1e9
    print(f"measured_podreduce[{n*4}B],{dt*1e6:.2f},{gbps:.2f}GB/s")
"""


def main() -> None:
    print(run_with_devices(CODE).strip())
    sys = get_active_system()
    beta = sys.link_bandwidth(Link.DCN)
    alpha = sys.link_latency(Link.DCN)
    for streams in (1, 2, 4):
        for size in (2**12, 2**16, 2**20, 2**24, 2**28):
            t = alpha + size / (beta * streams)
            emit(
                f"analytic_internode[{streams}streams,{size}B]",
                t * 1e6,
                f"{size / t / 1e9:.2f}GB/s",
            )


if __name__ == "__main__":
    main()
