"""Paper Fig. 17: LLM decode throughput vs physical memory placement.

Measured: the smoke-scale LM decoding N tokens with the KV cache and/or
weights placed in ``device`` vs ``pinned_host`` memory kinds (the CPU
runtime exposes both, so the *relative* placement effect is real).
Analytic: the planner's per-policy step-time prediction for the full
yi-6b / gemma3-27b configs — the paper's figure as a table.

Serve: the continuous-batching engine end-to-end with its zero-copy hot
path (donated caches, chunked batched prefill, on-device state), reporting
prefill and decode tokens/s *separately*, plus a queued-arrival workload
(requests arriving over time into an oversubscribed slot pool with
planner-priced preemption) reporting p50/p99 per-request completion
latency and time-to-first-token — all written to ``BENCH_serve.json`` so
CI records the serving-perf trajectory per commit.  ``--smoke`` runs only
these legs at smoke scale."""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs import SHAPES, get_config
from repro.core.placement import Role, get_policy, registered_policies
from repro.core.planner import decode_profile, predict
from repro.models import get_smoke_bundle
from repro.models.model_zoo import ModelBundle
from repro.models.sharding import defs_to_specs
from repro.launch.mesh import make_mesh_for


def measured() -> None:
    bundle = get_smoke_bundle("yi-6b")
    params = bundle.init_params(jax.random.PRNGKey(0))
    B, S, NEW = 4, 64, 32
    mesh = make_mesh_for((1,), ("data",))

    for policy_name in ("hbm_resident", "kv_host", "weights_stream"):
        policy = get_policy(policy_name)
        cache_kind = policy.memory_kind(Role.KV_CACHE)
        param_kind = policy.memory_kind(Role.PARAMS)
        cache_specs = defs_to_specs(
            bundle.cache_defs(B, S + NEW + 8), mesh, memory_kind=cache_kind
        )
        cache = jax.tree.map(
            jax.device_put, bundle.init_cache(B, S + NEW + 8), cache_specs
        )
        p = jax.tree.map(
            jax.device_put, params,
            defs_to_specs(bundle.param_defs(), mesh, memory_kind=param_kind),
        )
        toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                  bundle.cfg.vocab)
        # host-placed inputs are device_put to HBM INSIDE the jit (lowers
        # on CPU too); outputs come back in device memory and are re-pinned
        # to the policy tier outside jit each step — the streaming path.
        dev_param_specs = defs_to_specs(bundle.param_defs(), mesh)
        dev_cache_specs = defs_to_specs(
            bundle.cache_defs(B, S + NEW + 8), mesh
        )

        def gather(tree, specs):
            return jax.tree.map(jax.device_put, tree, specs)

        prefill = jax.jit(
            lambda p, b, c: bundle.prefill(
                gather(p, dev_param_specs), b, gather(c, dev_cache_specs)
            )
        )
        step = jax.jit(
            lambda p, b, c: bundle.decode_step(
                gather(p, dev_param_specs), b, gather(c, dev_cache_specs)
            )
        )
        logits, cache = prefill(p, {"tokens": toks}, cache)
        lengths = jnp.full((B,), S, jnp.int32)
        tok = jnp.argmax(logits, -1)[:, None]
        # warmup
        logits, c_dev = step(p, {"tokens": tok, "lengths": lengths}, cache)
        jax.block_until_ready(logits)
        t0 = time.perf_counter()
        cache = c_dev
        for i in range(NEW):
            if cache_kind != "device":
                cache = jax.tree.map(jax.device_put, cache, cache_specs)
            lengths = lengths + 1
            logits, cache = step(
                p, {"tokens": tok, "lengths": lengths}, cache
            )
            tok = jnp.argmax(logits, -1)[:, None]
        jax.block_until_ready(logits)
        dt = time.perf_counter() - t0
        emit(
            f"decode[{policy_name}]",
            dt / NEW * 1e6,
            f"{B*NEW/dt:.1f}tok/s",
        )


def analytic() -> None:
    shape = SHAPES["decode_32k"]
    for arch in ("yi-6b", "gemma3-27b", "deepseek-v2-236b"):
        bundle = ModelBundle(get_config(arch))
        prof = decode_profile(
            name=arch,
            param_bytes=bundle.cfg.num_params() * 2,
            kv_bytes=bundle.cache_bytes(shape),
            step_flops=bundle.model_flops(shape),
            num_chips=256,
        )
        for policy in registered_policies().values():
            pred = predict(prof, policy)
            emit(
                f"analytic_decode[{arch},{policy.name}]",
                pred.step_s * 1e6,
                f"{shape.global_batch/pred.step_s:.0f}tok/s"
                + ("" if pred.fits else " DOES-NOT-FIT"),
            )


def serve(out_path: str = "BENCH_serve.json", *, requests: int = 8,
          prompt_len: int = 24, max_new: int = 12,
          policy: str | None = None) -> dict:
    """Serve-loop throughput with the prefill/decode phases split out.

    One row (and one JSON entry) per measured configuration: the engine's
    own phase counters give prefill tokens/s (chunked batched admission)
    and decode tokens/s (donated-cache, on-device-state steps) — the two
    rates the datapath model prices separately.  Every entry embeds the
    serving policy's JSON (and, for planner-picked policies, the
    top-candidate explain table), so the artifact records *which
    placement* produced the numbers.
    """
    from repro.serve import Request, ServeConfig, Server

    arch = "yi-6b"
    bundle = get_smoke_bundle(arch)
    params = bundle.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    results = {}
    # a real (1-device) mesh so the policy is physically realized — the
    # recorded policy JSON must describe the placement that actually
    # held, not just the one configured
    mesh = make_mesh_for((1,), ("data",))
    for chunk in (8, 32):
        server = Server(
            bundle,
            ServeConfig(batch_slots=4, max_len=96, prefill_chunk=chunk,
                        policy=policy),
            params,
            mesh=mesh,
        )
        server.add_requests(
            Request(
                rid=i,
                prompt=rng.integers(
                    1, bundle.cfg.vocab, prompt_len
                ).astype(np.int32),
                max_new_tokens=max_new,
            )
            for i in range(requests)
        )
        server.run_until_done()
        tp = server.throughput()
        key = f"{arch},chunk{chunk}"
        results[key] = {
            "arch": arch,
            "prefill_chunk": chunk,
            "batch_slots": 4,
            "requests": requests,
            "prompt_len": prompt_len,
            "max_new": max_new,
            # policy JSON + mesh axes + per-phase explain tables: the
            # artifact records which placement produced the numbers
            **server.rt.describe(),
            **tp,
        }
        emit(
            f"serve_prefill[{key}]",
            1e6 / max(tp["prefill_tps"], 1e-9),
            f"{tp['prefill_tps']:.1f}tok/s",
        )
        emit(
            f"serve_decode[{key}]",
            1e6 / max(tp["decode_tps"], 1e-9),
            f"{tp['decode_tps']:.1f}tok/s",
        )
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
    return results


def queued(out_path: str = "BENCH_serve.json", *, requests: int = 16,
           prompt_len: int = 16, max_new: int = 8, batch_slots: int = 2,
           arrival_every: int = 2, policy: str | None = None) -> dict:
    """Queued-arrival workload: per-request latency under oversubscription.

    Unlike :func:`serve` (all requests submitted up front), requests
    arrive over time — one every ``arrival_every`` decode ticks — into a
    slot pool they oversubscribe, with planner-priced preemption on.
    Each request's ``submitted_s`` / ``first_token_s`` / ``finished_s``
    stamps yield queue-inclusive completion latency and time-to-first-
    token; the p50/p99 of both land in ``BENCH_serve.json`` alongside
    the throughput rows so CI tracks tail latency per commit.
    """
    from repro.serve import Request, SamplingParams, ServeConfig, Server

    arch = "yi-6b"
    bundle = get_smoke_bundle(arch)
    params = bundle.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    mesh = make_mesh_for((1,), ("data",))
    server = Server(
        bundle,
        ServeConfig(batch_slots=batch_slots, max_len=96, prefill_chunk=8,
                    policy=policy, max_queue=requests,
                    preempt=True, preempt_wait=4),
        params,
        mesh=mesh,
    )
    reqs = [
        Request(
            rid=i,
            prompt=rng.integers(1, bundle.cfg.vocab, prompt_len)
            .astype(np.int32),
            max_new_tokens=max_new,
            sampling=(SamplingParams() if i % 2 == 0 else
                      SamplingParams(temperature=0.8, top_k=20, seed=i)),
        )
        for i in range(requests)
    ]
    pending = list(reqs)
    tick = 0
    while pending or server.has_work():
        while pending and tick >= arrival_every * (len(reqs) - len(pending)):
            server.add_request(pending.pop(0))
        server.step()
        tick += 1
        assert tick < 50_000, "queued-arrival loop did not drain"
    assert all(r.done for r in reqs)

    lat = np.asarray([r.finished_s - r.submitted_s for r in reqs])
    ttft = np.asarray([r.first_token_s - r.submitted_s for r in reqs])
    stats = server.stats()
    tp = server.throughput()
    row = {
        "arch": arch,
        "batch_slots": batch_slots,
        "requests": requests,
        "prompt_len": prompt_len,
        "max_new": max_new,
        "arrival_every_ticks": arrival_every,
        "latency_p50_s": float(np.percentile(lat, 50)),
        "latency_p99_s": float(np.percentile(lat, 99)),
        "ttft_p50_s": float(np.percentile(ttft, 50)),
        "ttft_p99_s": float(np.percentile(ttft, 99)),
        "preemptions": stats["preemptions"],
        "promotions": stats["promotions"],
        "peak_queue": stats["peak_queue"],
        **server.rt.describe(),
        **tp,
    }
    emit(f"serve_queued_p50[{arch}]", row["latency_p50_s"] * 1e6,
         f"{row['latency_p50_s']*1e3:.1f}ms")
    emit(f"serve_queued_p99[{arch}]", row["latency_p99_s"] * 1e6,
         f"{row['latency_p99_s']*1e3:.1f}ms "
         f"({stats['preemptions']} preemptions)")
    try:
        with open(out_path) as f:
            results = json.load(f)
    except (OSError, ValueError):
        results = {}
    results[f"{arch},queued"] = row
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
    return row


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--smoke", action="store_true",
        help="serve-throughput smoke only (writes BENCH_serve.json)",
    )
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument(
        "--policy", default=None,
        help="force the serve leg's placement policy (registered name, "
             "role=tier[:strategy] grammar, or JSON); default: planner",
    )
    args, _ = ap.parse_known_args()
    if args.smoke:
        serve(args.out, requests=4, prompt_len=16, max_new=6,
              policy=args.policy)
        queued(args.out, requests=8, prompt_len=12, max_new=6,
               policy=args.policy)
        return
    measured()
    analytic()
    serve(args.out, policy=args.policy)
    queued(args.out, policy=args.policy)


if __name__ == "__main__":
    main()
