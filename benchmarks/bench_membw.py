"""Paper Figs. 2/7/8: read/write bandwidth per memory placement.

Measured mode: jnp read (sum) / write (fill) kernels over buffers placed in
``device`` vs ``pinned_host`` memory kinds — the placement axis the CPU
runtime exposes.  Analytic mode: the full TPU tier table with bound
fractions (the paper's headline metric)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import SingleDeviceSharding

from benchmarks.common import emit
from repro.core import MemoryTier, read_bound, write_bound
from repro.core.membench import dispatch_overhead, measure

SIZES = [2**20, 2**24, 2**27]  # 1 MiB .. 128 MiB


def _placed(nbytes: int, kind: str):
    x = jnp.ones((nbytes // 4,), jnp.float32)
    dev = jax.devices()[0]
    return jax.device_put(x, SingleDeviceSharding(dev, memory_kind=kind))


def main() -> None:
    emit("dispatch_overhead", dispatch_overhead() * 1e6, "per-call")

    read = jax.jit(lambda x: jnp.sum(x))
    write = jax.jit(lambda x: jnp.full_like(x, 2.0))

    kinds = ["device"]
    if "pinned_host" in {
        m.kind for m in jax.devices()[0].addressable_memories()
    }:
        kinds.append("pinned_host")

    for kind in kinds:
        for nbytes in SIZES:
            x = _placed(nbytes, kind)
            m = measure(
                lambda x=x: read(x), name=f"read[{kind},{nbytes}]",
                nbytes=nbytes,
            )
            emit(m.name, m.us_per_call, f"{m.gbps:.2f}GB/s")
            m = measure(
                lambda x=x: write(x), name=f"write[{kind},{nbytes}]",
                nbytes=nbytes,
            )
            emit(m.name, m.us_per_call, f"{m.gbps:.2f}GB/s")

    # analytic TPU tier table (Fig. 7's bound rows)
    for t in MemoryTier:
        if t == MemoryTier.VMEM:
            continue
        rb, wb = read_bound(t), write_bound(t)
        emit(
            f"analytic_read[{t}]", rb.latency * 1e6,
            f"{rb.bandwidth/1e9:.1f}GB/s",
        )
        emit(
            f"analytic_write[{t}]", wb.latency * 1e6,
            f"{wb.bandwidth/1e9:.1f}GB/s",
        )


if __name__ == "__main__":
    main()
