"""Paper Fig. 13: ping-pong latency between processing units.

TPU adaptation (DESIGN.md §2.1): the CAS ping-pong becomes a
``collective_permute`` round trip between mesh neighbors at increasing
topological distance — the quantity preserved is which hop dominates
small-message latency.  Measured on 8 host devices in a subprocess;
analytic rows give the ICI-hop/DCN ladder of the hardware model."""

from __future__ import annotations

from benchmarks.common import emit, run_with_devices
from repro.core import Link, get_active_system

CODE = """
import jax, jax.numpy as jnp, time
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P
from repro.launch.mesh import make_mesh_compat
mesh = make_mesh_compat((8,), ("x",))
x = jnp.arange(8.0).reshape(8, 1)
# single permute per dispatch (the two-permute program deadlocks the CPU
# backend's transfer manager); round trip = 2x one-way.
for dist in (1, 2, 4):
    fwd = [(i, (i + dist) % 8) for i in range(8)]
    f = jax.jit(shard_map(lambda v: jax.lax.ppermute(v, "x", fwd),
                          mesh=mesh, in_specs=P("x"), out_specs=P("x")))
    out = f(x); jax.block_until_ready(out)
    n = 30
    t0 = time.perf_counter()
    for _ in range(n):
        out = f(out)
    jax.block_until_ready(out)
    dt = 2 * (time.perf_counter() - t0) / n
    print(f"pingpong[dist={dist}],{dt*1e6:.2f},round-trip(2x one-way)")
"""


def main() -> None:
    print(run_with_devices(CODE).strip())
    # analytic ladder: 1 ICI hop, multi-hop, cross-pod (paper's G0/H0..H3)
    c = get_active_system()
    for hops in (1, 2, 4, 8):
        lat = 2 * hops * c.link_latency(Link.ICI)
        emit(f"analytic_pingpong[ici,{hops}hops]", lat * 1e6, "round-trip")
    lat = 2 * c.link_latency(Link.DCN)
    emit("analytic_pingpong[dcn]", lat * 1e6, "round-trip")
    lat = 2 * c.link_latency(Link.PCIE)
    emit("analytic_pingpong[host]", lat * 1e6, "round-trip")


if __name__ == "__main__":
    main()
