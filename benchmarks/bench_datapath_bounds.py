"""Paper Fig. 3 + Table II + Figs. 15-17: theoretical bound matrices, the
memory-API capability table, and the generated placement-policy table —
all from the datapath model (pure analysis, no device measurement).

The policy table is the planner's §IV decision surface: for a reference
full-size architecture, the predicted step time of **every** placement
policy in both the training and decode regimes, each time term derived
from the datapath bounds (read/copy/collective) — the Figs. 15-17 rows,
generated rather than hand-derived."""

from __future__ import annotations

from benchmarks.common import emit
from repro.core import (
    DEFAULT_SYSTEM,
    MemoryTier,
    POLICIES,
    bound_matrix,
    copy_bound,
    plan,
    read_bound,
)

TIERS = [t for t in MemoryTier if t != MemoryTier.VMEM]

POLICY_ARCH = "gemma3-27b"
POLICY_CHIPS = 256


def _emit_policy_table() -> None:
    """Figs. 15-17 analogue: predicted step time per policy per regime."""
    from repro.configs import SHAPES, get_config
    from repro.models.model_zoo import ModelBundle

    bundle = ModelBundle(get_config(POLICY_ARCH))
    # 256 chips as a (pod=2) x (data=16) x (model=8) mesh
    train = bundle.train_workload(
        SHAPES["train_4k"],
        num_chips=POLICY_CHIPS,
        data_axis_size=16,
        pod_axis_size=2,
    )
    decode = bundle.decode_workload(
        SHAPES["decode_32k"], num_chips=POLICY_CHIPS
    )
    for regime, prof in (("train", train), ("decode", decode)):
        best, preds = plan(prof)
        for p in preds:
            tag = "+best" if p.policy == best.policy else (
                "" if p.fits else "+nofit"
            )
            emit(
                f"policy[{regime}|{p.policy}]",
                p.step_s * 1e6,
                f"limited_by={p.limiting}|hbm={p.hbm_bytes/2**30:.2f}GiB{tag}",
            )


def main() -> None:
    # Fig. 3 (left): read/write bounds per tier
    for t in TIERS:
        b = read_bound(t)
        emit(
            f"bound_read[{t}]",
            b.latency * 1e6,
            f"{b.bandwidth/1e9:.1f}GB/s via {b.limiting_link}",
        )
    # Fig. 3 (right): copy bound matrix (the twice-traversed-halves rule)
    for src in TIERS:
        for dst in TIERS:
            b = copy_bound(src, dst)
            emit(
                f"bound_copy[{src}->{dst}]",
                b.latency * 1e6,
                f"{b.bandwidth/1e9:.1f}GB/s via {b.limiting_link}",
            )
    # Figs. 15-17: the generated per-policy step-time table
    _emit_policy_table()
    # Table II analogue: memory kinds the runtime actually exposes
    import jax

    kinds = [m.kind for m in jax.devices()[0].addressable_memories()]
    emit("memory_kinds", 0.0, "|".join(kinds))
    emit("policies", 0.0, "|".join(POLICIES))
    # headline numbers used throughout
    c = DEFAULT_SYSTEM.chip
    emit("chip_peak_bf16", 0.0, f"{c.peak_bf16_flops/1e12:.0f}TFLOP/s")
    emit("chip_hbm_bw", 0.0, f"{c.hbm_bandwidth/1e9:.0f}GB/s")
    emit("chip_host_dram_cap", 0.0, f"{c.host_dram_capacity/2**30:.0f}GiB")
    emit("ici_link_bw", 0.0, f"{c.ici_link_bandwidth/1e9:.0f}GB/s")
    emit("dcn_bw", 0.0, f"{c.dcn_bandwidth/1e9:.0f}GB/s")


if __name__ == "__main__":
    main()
