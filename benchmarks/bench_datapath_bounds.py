"""Paper Fig. 3 + Table II: theoretical bound matrices and the memory-API
capability table, from the datapath model (pure analysis, no devices)."""

from __future__ import annotations

from benchmarks.common import emit
from repro.core import (
    DEFAULT_SYSTEM,
    MemoryTier,
    bound_matrix,
    copy_bound,
    read_bound,
)

TIERS = [t for t in MemoryTier if t != MemoryTier.VMEM]


def main() -> None:
    # Fig. 3 (left): read/write bounds per tier
    for t in TIERS:
        b = read_bound(t)
        emit(
            f"bound_read[{t}]",
            b.latency * 1e6,
            f"{b.bandwidth/1e9:.1f}GB/s via {b.limiting_link}",
        )
    # Fig. 3 (right): copy bound matrix (the twice-traversed-halves rule)
    for src in TIERS:
        for dst in TIERS:
            b = copy_bound(src, dst)
            emit(
                f"bound_copy[{src}->{dst}]",
                b.latency * 1e6,
                f"{b.bandwidth/1e9:.1f}GB/s via {b.limiting_link}",
            )
    # Table II analogue: memory kinds the runtime actually exposes
    import jax

    kinds = [m.kind for m in jax.devices()[0].addressable_memories()]
    emit("memory_kinds", 0.0, "|".join(kinds))
    # headline numbers used throughout
    c = DEFAULT_SYSTEM.chip
    emit("chip_peak_bf16", 0.0, f"{c.peak_bf16_flops/1e12:.0f}TFLOP/s")
    emit("chip_hbm_bw", 0.0, f"{c.hbm_bandwidth/1e9:.0f}GB/s")
    emit("ici_link_bw", 0.0, f"{c.ici_link_bandwidth/1e9:.0f}GB/s")
    emit("dcn_bw", 0.0, f"{c.dcn_bandwidth/1e9:.0f}GB/s")


if __name__ == "__main__":
    main()
