"""Paper Fig. 3 + Table II + Figs. 15-17: theoretical bound matrices, the
memory-API capability table, and the generated placement-policy table —
from the datapath model, plus a **measured peer/remote column** whenever
this process sees >= 2 devices (CI runs one matrix leg under
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` so the donor-axis
datapath is exercised on every push).

The policy table is the planner's §IV decision surface: for a reference
full-size architecture, the predicted step time of **every** placement
policy in both the training and decode regimes, each time term derived
from the datapath bounds (read/copy/collective) — the Figs. 15-17 rows,
generated rather than hand-derived.  The measured column realizes the two
headline peer placements on a real donor mesh: an in-place reduction over
a donor-sharded buffer (``kv_peer_hbm``'s read path) and a
:class:`~repro.core.placement.DonorStream` double-buffered window sweep
(``weights_peer_hbm``'s layer-streaming path), each emitted next to its
``read_bound``/``copy_bound`` prediction.

When a calibration is active (``benchmarks.run --calibration`` or a
``calibration.json`` in the working directory) every bound row carries a
second, calibrated number and the measured column reports its
achieved-over-bound fraction against **both** the spec-sheet and the
calibrated system — how much calibration moved each prediction."""

from __future__ import annotations

import time

from benchmarks.common import emit
from repro.core import (
    DonorStream,
    MemoryTier,
    bound_matrix,
    copy_bound,
    get_active_system,
    plan,
    read_bound,
    registered_policies,
)
from repro.api import SPEC_SYSTEM

TIERS = [t for t in MemoryTier if t != MemoryTier.VMEM]

POLICY_ARCH = "gemma3-27b"
POLICY_CHIPS = 256


def _calibrated() -> bool:
    """Is the active system different from the spec sheet?"""
    return get_active_system() is not SPEC_SYSTEM


def _emit_policy_table() -> None:
    """Figs. 15-17 analogue: predicted step time per policy per regime.

    Under an active calibration each row also carries the spec-sheet
    prediction, so the table shows how much calibration moved each
    policy's step time (and potentially the pick)."""
    from repro.configs import SHAPES, get_config
    from repro.models.model_zoo import ModelBundle

    bundle = ModelBundle(get_config(POLICY_ARCH))
    # 256 chips as a (pod=2) x (data=16) x (model=8) mesh
    train = bundle.train_workload(
        SHAPES["train_4k"],
        num_chips=POLICY_CHIPS,
        data_axis_size=16,
        pod_axis_size=2,
    )
    decode = bundle.decode_workload(
        SHAPES["decode_32k"], num_chips=POLICY_CHIPS
    )
    for regime, prof in (("train", train), ("decode", decode)):
        best, preds = plan(prof)
        spec_preds = {}
        if _calibrated():
            _, sp = plan(prof, system=SPEC_SYSTEM)
            spec_preds = {p.policy: p for p in sp}
        for p in preds:
            tag = "+best" if p.policy == best.policy else (
                "" if p.fits else "+nofit"
            )
            extra = ""
            spec = spec_preds.get(p.policy)
            if spec is not None:
                extra = f"|spec_step={spec.step_s*1e6:.2f}us"
            emit(
                f"policy[{regime}|{p.policy}]",
                p.step_s * 1e6,
                f"limited_by={p.limiting}|hbm={p.hbm_bytes/2**30:.2f}GiB"
                f"{extra}{tag}",
            )


def _emit_measured_donor_column() -> None:
    """Measured peer/remote datapaths on a donor mesh (>= 2 devices).

    CPU host devices share one physical memory, so the measured number
    calibrates the *mechanism* (a forced gather across the donor axis,
    double-buffered window streaming), not the link bandwidth; on TPU the
    same code times the real ICI/DCN hop.  Single-device runs emit a skip
    marker instead — the analytic rows above are then the only
    peer/remote information.
    """
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    if jax.device_count() < 2:
        emit("peer_measured", 0.0,
             "skipped: 1 device, no donor axis "
             "(set XLA_FLAGS=--xla_force_host_platform_device_count=4)")
        return

    from repro.launch.mesh import make_donor_mesh

    n_windows, window_elems = 8, 1 << 20          # 8 x 4 MiB f32 windows
    nbytes = n_windows * window_elems * 4
    for tier, remote in ((MemoryTier.PEER_HBM, False),
                         (MemoryTier.REMOTE_HBM, True)):
        mesh = make_donor_mesh((1,), ("data",), 2, remote=remote)
        axis = "donor_pod" if remote else "donor"
        stack = jax.device_put(
            jnp.arange(n_windows * window_elems, dtype=jnp.float32)
            .reshape(n_windows, window_elems),
            NamedSharding(mesh, P(axis)),
        )
        # kv_peer_hbm's datapath: every donor-resident byte pulled to the
        # local slice.  A plain partitioned reduction would NOT measure
        # this (GSPMD computes on the donor shard and ships a scalar), so
        # force the full gather across the donor axis via out_shardings —
        # on TPU that is the ICI/DCN hop the read_bound prices.
        gather = jax.jit(
            lambda x: x + 0.0,
            out_shardings=NamedSharding(mesh, P()),
        )
        gather(stack).block_until_ready()          # compile
        t0 = time.perf_counter()
        iters = 8
        for _ in range(iters):
            gather(stack).block_until_ready()
        read_s = (time.perf_counter() - t0) / iters
        measured_bw = nbytes / read_s
        rb = read_bound(tier)
        frac = f"frac={rb.fraction(measured_bw):.3f}"
        if _calibrated():
            spec_rb = read_bound(tier, SPEC_SYSTEM)
            frac = (f"frac_cal={rb.fraction(measured_bw):.3f} "
                    f"frac_spec={spec_rb.fraction(measured_bw):.3f}")
        emit(
            f"peer_read_measured[{tier}]",
            read_s * 1e6,
            f"measured={measured_bw/1e9:.1f}GB/s "
            f"predicted<={rb.bandwidth/1e9:.1f}GB/s via {rb.limiting_link} "
            f"{frac}",
        )
        # weights_peer_hbm's datapath: double-buffered window streaming.
        # One full untimed sweep warms lazy runtime setup; the timed sweep
        # uses a fresh stream so all n_windows fetches land in the region.
        for w in DonorStream(stack, mesh, P(), n_windows):
            jax.block_until_ready(w)
        t0 = time.perf_counter()
        for w in DonorStream(stack, mesh, P(), n_windows):
            jax.block_until_ready(w)
        stream_s = time.perf_counter() - t0
        measured_bw = nbytes / stream_s
        cb = copy_bound(tier, MemoryTier.HBM)
        frac = f"frac={cb.fraction(measured_bw):.3f}"
        if _calibrated():
            spec_cb = copy_bound(tier, MemoryTier.HBM, SPEC_SYSTEM)
            frac = (f"frac_cal={cb.fraction(measured_bw):.3f} "
                    f"frac_spec={spec_cb.fraction(measured_bw):.3f}")
        emit(
            f"peer_stream_measured[{tier}]",
            stream_s * 1e6,
            f"measured={measured_bw/1e9:.1f}GB/s "
            f"predicted<={cb.bandwidth/1e9:.1f}GB/s via {cb.limiting_link} "
            f"{frac}",
        )


def main() -> None:
    cal = _calibrated()
    # Fig. 3 (left): read/write bounds per tier — spec + calibrated
    for t in TIERS:
        b = read_bound(t)
        extra = ""
        if cal:
            sb = read_bound(t, SPEC_SYSTEM)
            extra = f" spec={sb.bandwidth/1e9:.1f}GB/s"
        emit(
            f"bound_read[{t}]",
            b.latency * 1e6,
            f"{b.bandwidth/1e9:.1f}GB/s via {b.limiting_link}{extra}",
        )
    # Fig. 3 (right): copy bound matrix (the twice-traversed-halves rule)
    for src in TIERS:
        for dst in TIERS:
            b = copy_bound(src, dst)
            extra = ""
            if cal:
                sb = copy_bound(src, dst, SPEC_SYSTEM)
                extra = f" spec={sb.bandwidth/1e9:.1f}GB/s"
            emit(
                f"bound_copy[{src}->{dst}]",
                b.latency * 1e6,
                f"{b.bandwidth/1e9:.1f}GB/s via {b.limiting_link}{extra}",
            )
    # Figs. 15-17: the generated per-policy step-time table
    _emit_policy_table()
    # measured peer/remote column (donor mesh; skipped on 1 device)
    _emit_measured_donor_column()
    # Table II analogue: memory kinds the runtime actually exposes
    import jax

    kinds = [m.kind for m in jax.devices()[0].addressable_memories()]
    emit("memory_kinds", 0.0, "|".join(kinds))
    # the live registry, not a hand-written list: policies registered by
    # configs/plugins appear in the emitted table automatically
    emit("policies", 0.0, "|".join(registered_policies()))
    # headline numbers used throughout, with their provenance
    system = get_active_system()
    c = system.chip
    prov = system.provenance_of
    emit("chip_peak_bf16", 0.0,
         f"{c.peak_bf16_flops/1e12:.0f}TFLOP/s [{prov('peak_bf16_flops')}]")
    emit("chip_hbm_bw", 0.0,
         f"{c.hbm_bandwidth/1e9:.0f}GB/s [{prov('hbm_bandwidth')}]")
    emit("chip_host_dram_cap", 0.0, f"{c.host_dram_capacity/2**30:.0f}GiB")
    emit("ici_link_bw", 0.0,
         f"{c.ici_link_bandwidth/1e9:.0f}GB/s "
         f"[{prov('ici_link_bandwidth')}]")
    emit("dcn_bw", 0.0,
         f"{c.dcn_bandwidth/1e9:.0f}GB/s [{prov('dcn_bandwidth')}]")


if __name__ == "__main__":
    main()
