"""Render the EXPERIMENTS.md §Dry-run and §Roofline tables from the sweep
JSON: ``PYTHONPATH=src python tools/render_experiments.py results/dryrun_final.json``."""

import json
import sys


def main(path: str) -> None:
    recs = json.load(open(path))

    print("### Dry-run summary (per cell)\n")
    print("| arch | shape | mesh | status | compile (s) | args/dev (GiB) "
          "| peak/dev (GiB) | XLA flops/dev | notes |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in recs:
        if r["status"] == "skipped":
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | skipped "
                  f"| - | - | - | - | {r['reason']} |")
            continue
        ma = r["memory_analysis"]
        print(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['status']} "
            f"| {r['compile_s']:.1f} "
            f"| {ma['argument_bytes_per_device']/2**30:.2f} "
            f"| {ma['peak_bytes_per_device']/2**30:.2f} "
            f"| {r['cost_analysis']['xla_flops_per_device']:.3g} | |"
        )

    print("\n### Roofline (single-pod 16x16 baseline)\n")
    print("| arch | shape | compute (ms) | memory (ms) | collective (ms) "
          "| dominant | useful | frac | bw-frac | coll ICI/DCN (GB/dev) |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for r in recs:
        if r["status"] != "ok" or r["mesh"] != "16x16":
            continue
        rl = r["roofline"]
        ici = rl["collective_by_link"].get("ici", 0) / 1e9
        dcn = rl["collective_by_link"].get("dcn", 0) / 1e9
        print(
            f"| {r['arch']} | {r['shape']} "
            f"| {rl['compute_s']*1e3:.1f} | {rl['memory_s']*1e3:.1f} "
            f"| {rl['collective_s']*1e3:.1f} | {rl['dominant']} "
            f"| {rl['useful_ratio']:.2f} | {rl['roofline_fraction']:.1%} "
            f"| {rl['bw_fraction']:.1%} | {ici:.1f}/{dcn:.1f} |"
        )

    print("\n### Multi-pod (2x16x16) deltas\n")
    print("| arch | shape | peak/dev (GiB) | collective (ms) | DCN share |")
    print("|---|---|---|---|---|")
    for r in recs:
        if r["status"] != "ok" or r["mesh"] != "2x16x16":
            continue
        rl = r["roofline"]
        dcn = rl["collective_by_link"].get("dcn", 0)
        tot = max(rl["collective_bytes"], 1)
        print(
            f"| {r['arch']} | {r['shape']} "
            f"| {r['memory_analysis']['peak_bytes_per_device']/2**30:.2f} "
            f"| {rl['collective_s']*1e3:.1f} | {dcn/tot:.1%} |"
        )


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "results/dryrun_final.json")
