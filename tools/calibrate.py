#!/usr/bin/env python
"""Calibrate the hardware model and gate on replay drift.

``python tools/calibrate.py --out calibration.json --report
replay_report.json --gate 2.0`` runs the calibration microbenchmarks
(:func:`repro.core.calibration.calibrate`) on whatever devices this
process sees, writes the provenance-tagged ``calibration.json`` and the
per-term replay error report, prints a spec-vs-calibrated planner
comparison, and exits 1 when any term's mean predicted-vs-measured
relative error exceeds the gate.

The gate is a *drift* gate: replay predictions are made under the
calibrated constants, so large error means the linear cost model itself
no longer describes the machine (or the measurement was too noisy to
fit), not merely that the spec sheet was optimistic.  CI runs this loose
(``--gate 2.0`` on CPU-emulated hosts, where timer noise at small sizes
dominates); on real hardware the documented tight values apply — see
docs/calibration.md.

Run from the repo root:  ``PYTHONPATH=src python tools/calibrate.py``
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "src"))


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="calibration.json", metavar="PATH",
                    help="where to write the calibration (default: "
                         "./calibration.json)")
    ap.add_argument("--report", default="replay_report.json", metavar="PATH",
                    help="where to write the replay error report")
    ap.add_argument("--gate", type=float, default=None, metavar="REL_ERR",
                    help="fail (exit 1) when any term's mean relative "
                         "error exceeds this (e.g. 2.0 = 200%%; CI's "
                         "loose CPU value — real hardware should gate at "
                         "0.25-0.5, see docs/calibration.md)")
    ap.add_argument("--gate-term", action="append", default=[],
                    metavar="TERM=REL_ERR",
                    help="per-term gate override, repeatable "
                         "(e.g. --gate-term hbm_bandwidth=0.5)")
    ap.add_argument("--repeats", type=int, default=5)
    ap.add_argument("--sizes", default=None,
                    help="comma-separated sweep sizes in bytes")
    args = ap.parse_args()

    from repro.core.calibration import calibrate
    from repro.core.planner import plan, train_profile

    kwargs = {"repeats": args.repeats}
    if args.sizes:
        kwargs["sizes"] = tuple(int(s) for s in args.sizes.split(","))
    cal = calibrate(**kwargs)
    cal.save(args.out)
    print(cal.summary())
    print()
    print(cal.replay.report())

    # spec-vs-calibrated planner comparison on a reference profile: the
    # acceptance check that calibration actually moves predictions.
    calibrated = cal.apply()
    prof = train_profile(
        name="calibration-reference",
        param_bytes=2 * 27e9, step_flops=6 * 27e9 * 4096,
        activation_bytes=8 * 2**30, num_chips=256,
        data_axis_size=16, pod_axis_size=2,
    )
    spec_best, _ = plan(prof)
    cal_best, _ = plan(prof, system=calibrated)
    print()
    print(f"planner[spec]       pick={spec_best.policy} "
          f"step={spec_best.step_s*1e6:.2f}us limited_by="
          f"{spec_best.limiting}")
    print(f"planner[calibrated] pick={cal_best.policy} "
          f"step={cal_best.step_s*1e6:.2f}us limited_by="
          f"{cal_best.limiting}")

    report = {
        "per_term": {
            t: e.to_json() for t, e in cal.replay.per_term_error().items()
        },
        "gate": args.gate,
        "planner_comparison": {
            "spec": {"pick": spec_best.policy,
                     "step_s": spec_best.step_s,
                     "limiting": spec_best.limiting},
            "calibrated": {"pick": cal_best.policy,
                           "step_s": cal_best.step_s,
                           "limiting": cal_best.limiting},
        },
    }
    pathlib.Path(args.report).write_text(json.dumps(report, indent=2) + "\n")
    print(f"\nwrote {args.out} and {args.report}")

    if args.gate is not None:
        per_term = {}
        for spec in args.gate_term:
            term, _, value = spec.partition("=")
            per_term[term] = float(value)
        violations = cal.replay.gate(args.gate, per_term)
        if violations:
            print("\nDRIFT GATE FAILED:")
            for v in violations:
                print(f"  {v}")
            return 1
        print(f"\ndrift gate OK (mean rel error <= {args.gate:.0%} "
              "per term)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
