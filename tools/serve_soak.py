#!/usr/bin/env python
"""CI soak: the scheduler under sustained oversubscribed load.

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \\
        PYTHONPATH=src python tools/serve_soak.py [--requests 64]

What it asserts (ISSUE 6's scheduler acceptance criteria, as a tool the
4-device CI leg runs on every push):

1. ``--requests`` (>= 64) queued-arrival requests with mixed sampling
   params (greedy / temperature / top-k / top-p / stop tokens) all drain
   through an oversubscribed slot pool with planner-priced preemption
   enabled.
2. The run exercised **>= 1 preemption spill and >= 1 promotion** — the
   slot-rows round trip through the spill tier actually happened (on a
   >= 2 device runtime the mesh has a donor axis, so far tiers are
   realizable).
3. **No token divergence for the greedy subset**: every greedy request's
   tokens equal an unloaded (no-preemption) reference run — scheduling
   history is invisible in the output.
4. Per-request completion latency and time-to-first-token p50/p99 are
   merged into ``BENCH_serve.json`` so CI records tail latency under
   load per commit.
"""

from __future__ import annotations

import argparse
import json
import logging
import sys

import jax
import numpy as np

from repro.models import get_smoke_bundle
from repro.serve import Request, SamplingParams, ServeConfig, Server

log = logging.getLogger("repro.tools.serve_soak")


def make_sampling(i: int) -> SamplingParams:
    """Mixed params: half greedy, half seeded sampling variants."""
    if i % 2 == 0:
        return SamplingParams()                    # greedy subset
    variant = (i // 2) % 3
    if variant == 0:
        return SamplingParams(temperature=0.9, seed=i)
    if variant == 1:
        return SamplingParams(temperature=0.7, top_k=12, seed=i)
    return SamplingParams(temperature=1.1, top_p=0.9, seed=i)


def make_request(i: int, vocab: int, rng) -> Request:
    return Request(
        rid=i,
        prompt=rng.integers(1, vocab, 4 + (i % 5)).astype(np.int32),
        max_new_tokens=4 + (i % 9),
        sampling=make_sampling(i),
    )


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=48)
    ap.add_argument("--preempt-wait", type=int, default=4)
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO, format="%(message)s")

    bundle = get_smoke_bundle(args.arch)
    params = bundle.init_params(jax.random.PRNGKey(0), "float32")
    ndev = jax.device_count()
    if ndev >= 2:
        from repro.launch.mesh import make_donor_mesh
        mesh = make_donor_mesh((ndev // 2,), ("data",), 2)
    else:
        mesh = None
    rng = np.random.default_rng(0)
    reqs = [make_request(i, bundle.cfg.vocab, rng)
            for i in range(args.requests)]

    server = Server(
        bundle,
        ServeConfig(batch_slots=args.slots, max_len=args.max_len,
                    prefill_chunk=8, max_queue=args.requests,
                    preempt=True, preempt_wait=args.preempt_wait),
        params, mesh=mesh,
    )
    log.info("soak: %d requests -> %d slots on %d devices (policy %s, "
             "spill tier %s)", args.requests, args.slots, ndev,
             server.policy.name, server.rt.spill_placement().to_str())

    # queued arrivals: one new request per decode tick
    pending = list(reqs)
    tick = 0
    while pending or server.has_work():
        if pending:
            server.add_request(pending.pop(0))
        server.step()
        tick += 1
        if tick > 100_000:
            log.error("soak did not drain after %d ticks", tick)
            return 1
    if not all(r.done for r in reqs):
        log.error("undrained requests: %s",
                  [r.rid for r in reqs if not r.done])
        return 1

    stats = server.stats()
    if stats["preemptions"] < 1 or stats["promotions"] < 1:
        log.error("soak never exercised preemption (preemptions=%d, "
                  "promotions=%d) — lower --preempt-wait or raise "
                  "--requests", stats["preemptions"], stats["promotions"])
        return 1

    # greedy subset: token equality vs an unloaded (no-preemption) run
    ref_server = Server(
        bundle,
        ServeConfig(batch_slots=args.slots, max_len=args.max_len,
                    prefill_chunk=8),
        params, mesh=mesh,
    )
    greedy = [r for r in reqs if r.sampling.temperature == 0.0]
    refs = {
        r.rid: Request(rid=r.rid, prompt=r.prompt,
                       max_new_tokens=r.max_new_tokens)
        for r in greedy
    }
    ref_server.add_requests(refs.values())
    ref_server.run_until_done(100_000)
    diverged = [
        r.rid for r in greedy if r.out_tokens != refs[r.rid].out_tokens
    ]
    if diverged:
        log.error("greedy token divergence under load for rids %s",
                  diverged)
        return 1

    lat = np.asarray([r.finished_s - r.submitted_s for r in reqs])
    ttft = np.asarray([r.first_token_s - r.submitted_s for r in reqs])
    row = {
        "arch": bundle.cfg.name,
        "devices": ndev,
        "requests": args.requests,
        "batch_slots": args.slots,
        "preemptions": stats["preemptions"],
        "promotions": stats["promotions"],
        "peak_queue": stats["peak_queue"],
        "spill_s": stats["spill_s"],
        "restore_s": stats["restore_s"],
        "spill_tier": server.rt.spill_placement().to_str(),
        "latency_p50_s": float(np.percentile(lat, 50)),
        "latency_p99_s": float(np.percentile(lat, 99)),
        "ttft_p50_s": float(np.percentile(ttft, 50)),
        "ttft_p99_s": float(np.percentile(ttft, 99)),
        **server.throughput(),
    }
    try:
        with open(args.out) as f:
            results = json.load(f)
    except (OSError, ValueError):
        results = {}
    results["soak"] = row
    with open(args.out, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)

    log.info(
        "OK: %d requests drained through %d preemptions / %d promotions "
        "(spill -> %s); greedy subset (%d requests) token-identical to "
        "unloaded run; latency p50 %.0fms p99 %.0fms, ttft p50 %.0fms "
        "p99 %.0fms -> %s",
        args.requests, stats["preemptions"], stats["promotions"],
        row["spill_tier"], len(greedy),
        row["latency_p50_s"] * 1e3, row["latency_p99_s"] * 1e3,
        row["ttft_p50_s"] * 1e3, row["ttft_p99_s"] * 1e3, args.out,
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
