#!/usr/bin/env python
"""CI smoke: a custom (non-registered) policy serves through ``Runtime``
and survives one live ``replan()`` migration with identical output.

    PYTHONPATH=src python tools/policy_smoke.py \\
        --policy "kv=host:stream" [--target kv_peer_hbm]

What it asserts (the ISSUE 5 acceptance criterion, as a tool the
4-device CI leg runs on every push):

1. ``--policy`` (compact grammar or JSON, deliberately NOT a registered
   name) builds a :class:`~repro.core.placement.PlacementPolicy` value
   that serves the smoke config end-to-end through the
   :class:`repro.api.Runtime` facade.
2. Mid-serve, ``Server.replan(target)`` migrates the live KV cache (and
   params, if their placement changed) to ``--target`` — on a >= 2
   device runtime that is a real cross-device move onto a donor mesh
   axis.
3. The greedy tokens of the migrated run are **identical** to an
   uninterrupted static-policy run: migration is a placement change,
   never a recompute.
"""

from __future__ import annotations

import argparse
import json
import logging
import sys

import jax
import numpy as np

from repro.core.placement import parse_policy, registered_policies
from repro.launch.mesh import make_donor_mesh, make_mesh_for
from repro.models import get_smoke_bundle
from repro.serve import Request, ServeConfig, Server

log = logging.getLogger("repro.tools.policy_smoke")


def serve_tokens(bundle, params, mesh, policy, *, requests: int,
                 prompt_len: int, max_new: int,
                 migrate_at: int | None = None, target=None):
    """One serve run; optionally a live migration after ``migrate_at``
    steps.  Returns (per-request token lists, server)."""
    server = Server(
        bundle,
        ServeConfig(batch_slots=2, max_len=48, prefill_chunk=4,
                    policy=policy),
        params, mesh=mesh,
    )
    rng = np.random.default_rng(0)
    reqs = [
        Request(
            rid=i,
            prompt=rng.integers(1, bundle.cfg.vocab, prompt_len)
            .astype(np.int32),
            max_new_tokens=max_new,
        )
        for i in range(requests)
    ]
    server.add_requests(reqs)
    steps = 0
    while server.has_work():
        server.step()
        steps += 1
        if migrate_at is not None and steps == migrate_at:
            if not server.replan(target):
                raise SystemExit(
                    f"replan({target!r}) did not migrate (policy already "
                    f"{server.policy.name})"
                )
        if steps > 500:
            raise SystemExit("serve loop did not drain")
    return [r.out_tokens for r in reqs], server


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--policy", default="kv=host:stream",
        help="custom serving policy (compact grammar or JSON); must NOT "
             "be a registered name — the point is exercising the "
             "compositional path",
    )
    ap.add_argument(
        "--target", default=None,
        help="migration target for the mid-serve replan (any policy "
             "spelling); default: kv_peer_hbm with >= 2 devices, else "
             "hbm_resident",
    )
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=8)
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO, format="%(message)s")

    policy = parse_policy(args.policy)
    if args.policy in registered_policies():
        raise SystemExit(
            f"--policy {args.policy!r} is a registered name; pass a "
            "custom string/JSON policy (e.g. 'kv=host:stream')"
        )
    ndev = jax.device_count()
    if ndev >= 2:
        mesh = make_donor_mesh((ndev // 2,), ("data",), 2)
        target = args.target or "kv_peer_hbm"
    else:
        mesh = make_mesh_for((1,), ("data",))
        target = args.target or "hbm_resident"
    log.info(
        "policy smoke: %s devices, custom policy %s -> migrate to %s",
        ndev, policy.name, target,
    )

    bundle = get_smoke_bundle(args.arch)
    params = bundle.init_params(jax.random.PRNGKey(0), "float32")
    kw = dict(requests=args.requests, prompt_len=args.prompt_len,
              max_new=args.max_new)

    base, _ = serve_tokens(bundle, params, mesh, policy, **kw)
    # a mid-serve replan migration must not change a single greedy token
    moved, server = serve_tokens(
        bundle, params, mesh, policy, migrate_at=3, target=target, **kw
    )
    if base != moved:
        log.error("token mismatch across migration:\n  static:   %s\n  "
                  "migrated: %s", base, moved)
        return 1
    if server.stats()["migrations"] != 1:
        log.error("expected exactly 1 migration, got %d",
                  server.stats()["migrations"])
        return 1
    log.info(
        "OK: %d requests served under %s, one live migration to %s, "
        "greedy tokens identical; final policy JSON:\n%s",
        args.requests, policy.name, server.policy.name,
        json.dumps(json.loads(server.policy.to_json()), indent=2),
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
