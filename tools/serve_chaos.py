#!/usr/bin/env python
"""CI chaos soak: the serve loop must self-heal under a seeded fault plan.

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \\
        PYTHONPATH=src python tools/serve_chaos.py [--requests 64] [--seed 0]

The robustness acceptance criteria, as a tool the 4-device CI leg runs on
every push:

1. ``--requests`` (>= 64) queued-arrival requests drain under a seeded
   :class:`repro.core.faults.FaultPlan` carrying >= 1 donor-tier loss,
   >= 1 failed (transient) migration, >= 1 stalled dispatch, and one
   corrupted spill round trip.  Every request reaches a terminal state;
   the loop is bounded, so a hang is a hard failure, not a timeout.
2. The tier loss triggered **>= 1 successful evacuation** that actually
   re-placed a role off the lost tier, and the injected migration
   failure was retried (``migration_retries >= 1``).
3. **Greedy tokens are bit-identical to a no-fault reference run** — the
   recovery paths (bit-preserving evacuation migrate, replay-as-fresh
   after spill corruption or tier loss) are invisible in the output.
4. Completion rate, evacuations, retries, and tail latency under faults
   are merged into ``BENCH_chaos.json`` together with the full fault
   schedule and its firing record.

On a single-device runtime no donor tier is realizable, so the plan
degrades to stall + spill corruption and the evacuation assertions are
skipped (the CI chaos leg always runs with 4 host devices).
"""

from __future__ import annotations

import argparse
import json
import logging
import sys

import jax
import numpy as np

from repro.core.faults import FaultEvent, FaultKind, FaultPlan
from repro.models import get_smoke_bundle
from repro.serve import Request, ServeConfig, Server

from serve_soak import make_request

log = logging.getLogger("repro.tools.serve_chaos")


def build_plan(seed: int, multi_device: bool) -> FaultPlan:
    """Seeded schedule: the rng picks *when*, the structure is fixed.

    The transient MIGRATE_FAIL sits at migrate pass 0 — the serve loop's
    only ``migrate()`` calls are the evacuation's ``migrate_roles``, so
    the first migration attempt after the tier loss fails and must be
    retried.  The SPILL_CORRUPT hits the first preemption spill, early
    enough that its promotion (and checksum verification) lands before
    the tier loss does.
    """
    rng = np.random.default_rng(seed)
    events = [
        FaultEvent("decode", at=int(rng.integers(8, 16)),
                   kind=FaultKind.STALL, seconds=1.0),
        FaultEvent("spill", at=0, kind=FaultKind.SPILL_CORRUPT),
    ]
    if multi_device:
        events += [
            FaultEvent("decode", at=int(rng.integers(28, 44)),
                       kind=FaultKind.TIER_LOSS, tier="peer_hbm"),
            FaultEvent("migrate", at=0, kind=FaultKind.MIGRATE_FAIL,
                       error="transient"),
        ]
    return FaultPlan(events, seed=seed)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=48)
    ap.add_argument("--preempt-wait", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_chaos.json")
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO, format="%(message)s")

    bundle = get_smoke_bundle(args.arch)
    params = bundle.init_params(jax.random.PRNGKey(0), "float32")
    ndev = jax.device_count()
    if ndev >= 2:
        from repro.launch.mesh import make_donor_mesh
        mesh = make_donor_mesh((ndev // 2,), ("data",), 2)
        # pin KV onto the donor tier the plan is about to lose, so the
        # evacuation has something real to move
        policy = "kv_peer_hbm"
    else:
        mesh, policy = None, None
    plan = build_plan(args.seed, multi_device=mesh is not None)
    rng = np.random.default_rng(args.seed)
    reqs = [make_request(i, bundle.cfg.vocab, rng)
            for i in range(args.requests)]

    server = Server(
        bundle,
        ServeConfig(batch_slots=args.slots, max_len=args.max_len,
                    prefill_chunk=8, max_queue=args.requests,
                    preempt=True, preempt_wait=args.preempt_wait,
                    policy=policy, faults=plan, verify_spills=True),
        params, mesh=mesh,
    )
    log.info("chaos: %d requests -> %d slots on %d devices (policy %s), "
             "%d scheduled faults (seed %d)", args.requests, args.slots,
             ndev, server.policy.name, len(plan.events), args.seed)

    # queued arrivals: one new request per tick; the loop is bounded so
    # a hang under faults fails loudly instead of wedging CI
    pending = list(reqs)
    tick = 0
    while pending or server.has_work():
        if pending:
            server.add_request(pending.pop(0))
        server.step()
        tick += 1
        if tick > 100_000:
            log.error("chaos soak did not drain after %d ticks", tick)
            return 1
    undrained = [r.rid for r in reqs if not r.done]
    if undrained:
        log.error("non-terminal requests after drain: %s", undrained)
        return 1

    stats = server.stats()
    fired_kinds = {ev.kind for _site, _idx, ev in plan.fired}
    want = {FaultKind.STALL, FaultKind.SPILL_CORRUPT}
    if mesh is not None:
        want |= {FaultKind.TIER_LOSS, FaultKind.MIGRATE_FAIL}
    missing = want - fired_kinds
    if missing:
        log.error("scheduled fault kinds never fired: %s "
                  "(fired: %s) — re-tune the plan windows",
                  sorted(k.value for k in missing), plan.to_json()["fired"])
        return 1
    if mesh is not None:
        if stats["tier_losses"] < 1 or stats["evacuations"] < 1:
            log.error("tier loss did not drive an evacuation "
                      "(tier_losses=%d, evacuations=%d)",
                      stats["tier_losses"], stats["evacuations"])
            return 1
        if stats["migration_retries"] < 1:
            log.error("injected migration failure was never retried")
            return 1
    if stats["preemptions"] < 1 or stats["requeued_fresh"] < 1:
        log.error("spill corruption path not exercised (preemptions=%d, "
                  "requeued_fresh=%d) — raise --requests or lower "
                  "--preempt-wait", stats["preemptions"],
                  stats["requeued_fresh"])
        return 1

    # greedy subset: bit-identity vs a fault-free, preemption-free run
    ref_server = Server(
        bundle,
        ServeConfig(batch_slots=args.slots, max_len=args.max_len,
                    prefill_chunk=8, policy=policy),
        params, mesh=mesh,
    )
    greedy = [r for r in reqs if r.sampling.temperature == 0.0]
    refs = {
        r.rid: Request(rid=r.rid, prompt=r.prompt,
                       max_new_tokens=r.max_new_tokens)
        for r in greedy
    }
    ref_server.add_requests(refs.values())
    ref_server.run_until_done(100_000)
    diverged = [
        r.rid for r in greedy if r.out_tokens != refs[r.rid].out_tokens
    ]
    if diverged:
        log.error("greedy token divergence under faults for rids %s",
                  diverged)
        return 1

    lat = np.asarray([r.finished_s - r.submitted_s for r in reqs])
    row = {
        "arch": bundle.cfg.name,
        "devices": ndev,
        "requests": args.requests,
        "completed": sum(r.done for r in reqs),
        "completion_rate": sum(r.done for r in reqs) / len(reqs),
        "policy": server.policy.name,
        "tier_losses": stats["tier_losses"],
        "evacuations": stats["evacuations"],
        "migration_retries": stats["migration_retries"],
        "spill_corruptions": stats["spill_corruptions"],
        "requeued_fresh": stats["requeued_fresh"],
        "watchdog_stalls": stats["watchdog_stalls"],
        "watchdog_retries": stats["watchdog_retries"],
        "watchdog_evacuations": stats["watchdog_evacuations"],
        "preemptions": stats["preemptions"],
        "promotions": stats["promotions"],
        "latency_p50_s": float(np.percentile(lat, 50)),
        "latency_p99_s": float(np.percentile(lat, 99)),
        "fault_plan": plan.to_json(),
        **server.throughput(),
    }
    try:
        with open(args.out) as f:
            results = json.load(f)
    except (OSError, ValueError):
        results = {}
    results["chaos"] = row
    with open(args.out, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)

    log.info(
        "OK: %d/%d requests terminal under %d fired faults (%d tier "
        "losses -> %d evacuations, %d migration retries, %d requeued "
        "fresh); greedy subset (%d requests) bit-identical to no-fault "
        "run; latency p50 %.0fms p99 %.0fms -> %s",
        row["completed"], args.requests, len(plan.fired),
        row["tier_losses"], row["evacuations"], row["migration_retries"],
        row["requeued_fresh"], len(greedy),
        row["latency_p50_s"] * 1e3, row["latency_p99_s"] * 1e3, args.out,
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
