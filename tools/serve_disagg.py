#!/usr/bin/env python
"""CI soak: the disaggregated prefill/decode cluster end to end.

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \\
        PYTHONPATH=src python tools/serve_disagg.py [--requests 64]

What it asserts (the disaggregation acceptance criteria, as a tool the
4-device CI leg runs on every push):

1. ``--requests`` (>= 64) queued-arrival requests with mixed sampling
   params drain through a 2-prefill + 2-decode pool split joined by the
   DCN handoff (``repro.serve.disagg``).
2. **Every admitted request's KV crossed the donor_pod tier exactly
   once** — the handoff ledger records one completed publish→adopt round
   trip per rid (fault-recovered rids republish, but still adopt once).
3. **>= 1 injected handoff fault recovered**: a lost ticket and a
   corrupted transfer both replay as fresh through the prefill pool and
   the requests still finish.
4. **No token divergence for the greedy subset** vs a colocated baseline
   on a mesh shaped like the decode pool — disaggregation is invisible
   in the output.
5. Handoff bytes and publish/adopt latency percentiles, plus measured
   handoff bandwidth next to the calibrated ``dcn`` ``copy_bound``
   price, are merged into ``BENCH_disagg.json`` so CI records the
   crossing cost per commit.
"""

from __future__ import annotations

import argparse
import json
import logging
import sys

import jax
import numpy as np

from repro.core.faults import FaultEvent, FaultKind, FaultPlan
from repro.models import get_smoke_bundle
from repro.serve import Cluster, DisaggConfig, Request, ServeConfig, Server
from repro.serve.disagg import make_pool_mesh

from serve_soak import make_request

log = logging.getLogger("repro.tools.serve_disagg")


def percentiles(xs) -> dict:
    arr = np.asarray(xs, float)
    if arr.size == 0:
        return {"p50_s": 0.0, "p99_s": 0.0}
    return {
        "p50_s": float(np.percentile(arr, 50)),
        "p99_s": float(np.percentile(arr, 99)),
    }


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=48)
    ap.add_argument("--prefill-pool", type=int, default=2)
    ap.add_argument("--decode-pool", type=int, default=2)
    ap.add_argument("--out", default="BENCH_disagg.json")
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO, format="%(message)s")

    ndev = jax.device_count()
    need = args.prefill_pool + args.decode_pool
    if ndev < need:
        log.error(
            "disagg soak needs %d devices (%d prefill + %d decode), "
            "have %d — run under "
            "XLA_FLAGS=--xla_force_host_platform_device_count=%d",
            need, args.prefill_pool, args.decode_pool, ndev, need,
        )
        return 1

    bundle = get_smoke_bundle(args.arch)
    params = bundle.init_params(jax.random.PRNGKey(0), "float32")
    rng = np.random.default_rng(0)
    reqs = [make_request(i, bundle.cfg.vocab, rng)
            for i in range(args.requests)]

    # two handoff-site faults mid-stream: a ticket lost on the DCN path
    # and a transfer corrupted in flight — both must recover by
    # replaying through the prefill pool
    plan = FaultPlan([
        FaultEvent(site="handoff", at=5, kind=FaultKind.TICKET_LOSS),
        FaultEvent(site="handoff", at=11, kind=FaultKind.SPILL_CORRUPT),
    ])
    cluster = Cluster(
        bundle,
        DisaggConfig(
            batch_slots=args.slots,
            max_len=args.max_len,
            prefill_chunk=8,
            split=f"prefill:{args.prefill_pool},decode:{args.decode_pool}",
            max_queue=args.requests,
            faults=plan,
        ),
        params,
    )
    log.info(
        "disagg soak: %d requests -> %s on %d devices (decode policy %s)",
        args.requests, cluster.split.to_str(), ndev,
        cluster.decode.policy.name,
    )

    # queued arrivals: one new request per cluster tick
    pending = list(reqs)
    tick = 0
    while pending or cluster.has_work():
        if pending:
            cluster.add_request(pending.pop(0))
        cluster.step()
        tick += 1
        if tick > 100_000:
            log.error("disagg soak did not drain after %d ticks", tick)
            return 1
    if not all(r.done for r in reqs):
        log.error("undrained requests: %s",
                  [r.rid for r in reqs if not r.done])
        return 1

    stats = cluster.stats()
    led = cluster.ledger

    # every admitted rid crossed donor_pod exactly once
    bad = [r.rid for r in reqs if led.crossings(r.rid) != 1]
    if bad:
        log.error("rids without exactly one donor_pod crossing: %s "
                  "(adopts=%s)", bad, led.adopts)
        return 1
    # both injected handoff faults fired and recovered
    if len(plan.fired) < 2 or stats["handoff_replays"] < 2:
        log.error(
            "handoff faults not exercised: fired=%d replays=%d",
            len(plan.fired), stats["handoff_replays"],
        )
        return 1
    if stats["handoff"]["lost"] < 2:
        log.error("ledger did not record the lost crossings: %s",
                  stats["handoff"])
        return 1

    # greedy subset: token equality vs a colocated baseline on a mesh
    # shaped like the decode pool (same device count -> same compiled
    # steps -> bit-identical greedy tokens)
    ref_mesh = make_pool_mesh(
        jax.devices()[args.prefill_pool:args.prefill_pool
                      + args.decode_pool]
    )
    ref_server = Server(
        bundle,
        ServeConfig(batch_slots=args.slots, max_len=args.max_len,
                    prefill_chunk=8),
        params, mesh=ref_mesh,
    )
    greedy = [r for r in reqs if r.sampling.temperature == 0.0]
    refs = {
        r.rid: Request(rid=r.rid, prompt=r.prompt,
                       max_new_tokens=r.max_new_tokens)
        for r in greedy
    }
    ref_server.add_requests(refs.values())
    ref_server.run_until_done(100_000)
    diverged = [
        r.rid for r in greedy if r.out_tokens != refs[r.rid].out_tokens
    ]
    if diverged:
        log.error("greedy divergence vs colocated baseline for rids %s",
                  diverged)
        return 1

    # measured crossing cost vs the calibrated dcn copy_bound price
    publishes = [r for r in led.records if r["event"] == "publish"]
    adopts = [r for r in led.records if r["event"] == "adopt"]
    pub_s = sum(r["seconds"] for r in publishes)
    pub_bytes = led.total_bytes("publish")
    bound_s = sum(r["bound_s"] for r in publishes)
    lat = np.asarray([r.finished_s - r.submitted_s for r in reqs])
    ttft = np.asarray([r.first_token_s - r.submitted_s for r in reqs])
    row = {
        "arch": bundle.cfg.name,
        "devices": ndev,
        "requests": args.requests,
        "batch_slots": args.slots,
        "split": cluster.split.to_str(),
        "published": stats["handoff"]["published"],
        "adopted": stats["handoff"]["adopted"],
        "lost": stats["handoff"]["lost"],
        "handoff_replays": stats["handoff_replays"],
        "bytes_published": pub_bytes,
        "bytes_adopted": led.total_bytes("adopt"),
        "publish": percentiles([r["seconds"] for r in publishes]),
        "adopt": percentiles([r["seconds"] for r in adopts]),
        "measured_publish_gbps": (
            pub_bytes / pub_s / 1e9 if pub_s > 0 else 0.0
        ),
        "dcn_bound_gbps": (
            pub_bytes / bound_s / 1e9 if bound_s > 0 else 0.0
        ),
        "latency_p50_s": float(np.percentile(lat, 50)),
        "latency_p99_s": float(np.percentile(lat, 99)),
        "ttft_p50_s": float(np.percentile(ttft, 50)),
        "ttft_p99_s": float(np.percentile(ttft, 99)),
        **cluster.throughput(),
    }
    try:
        with open(args.out) as f:
            results = json.load(f)
    except (OSError, ValueError):
        results = {}
    results["disagg"] = row
    results["faults"] = plan.to_json()
    with open(args.out, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)

    log.info(
        "OK: %d requests drained through %s; every rid crossed "
        "donor_pod exactly once (%d published / %d adopted / %d lost, "
        "%d fault replays); greedy subset (%d requests) token-identical "
        "to the colocated baseline; publish p50 %.1fms (measured "
        "%.3g GB/s vs dcn bound %.3g GB/s) -> %s",
        args.requests, cluster.split.to_str(),
        row["published"], row["adopted"], row["lost"],
        row["handoff_replays"], len(greedy),
        row["publish"]["p50_s"] * 1e3, row["measured_publish_gbps"],
        row["dcn_bound_gbps"], args.out,
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
