"""Scale what-if: project a cell's roofline terms to 1000+ node fleets.

    PYTHONPATH=src python tools/whatif_scale.py --arch gemma3-27b

Uses the datapath model to extrapolate the per-step DCN gradient traffic,
ICI collective share, and HBM residency as pods are added (weak scaling on
the pod axis: global batch grows with pods), and shows where the two
framework levers — int8 gradient compression and pipeline-over-pods — pay.
This is the design analysis behind the "1000+ nodes" requirement: all
terms come from `core/hardware.py` + `core/datapath.py`.
"""

import argparse

from repro.configs import SHAPES, get_config
from repro.core.datapath import wire_bytes
from repro.core.hardware import get_active_system
from repro.models.model_zoo import ModelBundle


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-27b")
    ap.add_argument("--grad-bytes-per-param", type=float, default=2.0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    bundle = ModelBundle(cfg)
    shape = SHAPES["train_4k"]
    system = get_active_system()
    chip = system.chip
    pod_chips = system.pod.num_chips

    params = cfg.num_params()
    grad_bytes = params * args.grad_bytes_per_param

    print(f"{cfg.name}: {params/1e9:.1f}B params, weak scaling on the pod "
          f"axis (per-pod batch {shape.global_batch})\n")
    print(f"{'pods':>5s} {'chips':>7s} {'DCN grad AR (s)':>16s} "
          f"{'w/ int8 (s)':>12s} {'pipeline (s)':>13s} "
          f"{'compute/pod (s)':>16s} {'verdict':>24s}")

    # per-pod compute time for its share of the batch
    flops_per_pod = bundle.model_flops(shape) / pod_chips
    t_compute = flops_per_pod / chip.peak_bf16_flops

    # pipeline alternative: ship microbatch boundary activations instead
    act_bytes = (
        2.0 * shape.global_batch * shape.seq_len * cfg.d_model
    )  # bf16 boundary activations per pod-hop per step

    for pods in (2, 4, 8, 16, 32, 64):
        chips = pods * pod_chips
        # cross-pod gradient all-reduce: per-chip shard of grads, ring over pods
        payload = grad_bytes / pod_chips
        t_dcn = wire_bytes("all-reduce", payload, pods) / chip.dcn_bandwidth
        t_dcn_q = t_dcn / 4.0  # int8 + scales
        t_pipe = act_bytes / pod_chips / chip.dcn_bandwidth
        verdict = (
            "compute-bound" if t_compute > max(t_dcn_q, t_pipe)
            else ("compression sufficient" if t_dcn_q < t_compute
                  else "pipeline the pod axis")
        )
        print(f"{pods:5d} {chips:7d} {t_dcn:16.3f} {t_dcn_q:12.3f} "
              f"{t_pipe:13.3f} {t_compute:16.3f} {verdict:>24s}")

    print(
        "\nInterpretation: the DCN gradient all-reduce approaches "
        "2*grad_bytes/(pod_chips*dcn_bw) as pods grow (ring factor "
        "saturates) — the fleet-size-independent wall the paper's "
        "datapath analysis predicts; int8 compression buys 4x, and "
        "pipelining swaps gradient bytes for microbatch activations."
    )


if __name__ == "__main__":
    main()
