#!/usr/bin/env python
"""Repo data-movement audit gate (CI): lint + compiled-HLO transfer audit.

Three sections, each optional:

* ``--lint``       — run every registered :mod:`repro.analysis.lint` rule
  over the repo (src/tests/examples/benchmarks/tools).
* ``--hlo-audit``  — build the smoke-config serve Executor and audit its
  compiled decode/prefill/insert modules against the policy's movement
  contract (donation coverage, host↔device budget, planner byte plan).
* ``--selftest``   — prove the gate actually trips: inject one violation
  of each class (lint rule, missed donation, forbidden donation, stray
  host transfer) and fail unless every one is caught.

Writes ``--out audit_report.json`` (CI artifact) and exits 1 on any
error-severity violation or selftest miss.

Run from the repo root:  ``PYTHONPATH=src python tools/audit.py --lint
--hlo-audit --selftest --out audit_report.json``
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "src"))


# ---------------------------------------------------------------------------
# --lint
# ---------------------------------------------------------------------------

def run_lint() -> dict:
    from repro.analysis import lint

    violations = lint.lint_repo(REPO)
    for v in violations:
        print(f"  {v}")
    return {
        "violations": [v.to_json() for v in violations],
        "rules": sorted(lint.registered_rules()),
        "ok": not any(v.severity == "error" for v in violations),
    }


# ---------------------------------------------------------------------------
# --hlo-audit
# ---------------------------------------------------------------------------

def run_hlo_audit() -> dict:
    import jax
    from repro.models import get_smoke_bundle
    from repro.serve import Server, ServeConfig

    bundle = get_smoke_bundle("olmo-1b")
    params = bundle.init_params(jax.random.PRNGKey(0), "float32")
    srv = Server(
        bundle,
        ServeConfig(batch_slots=2, max_len=48, prefill_chunk=4),
        params,
    )
    reports = {
        name: report.to_json()
        for name, report in srv.engine.audit_reports.items()
    }
    ok = all(r["ok"] for r in reports.values())
    for name, r in reports.items():
        print(
            f"  {name}: donation {r['donation_materialized']}/"
            f"{r['donation_expected']}, host bytes "
            f"{r['host_transfer_bytes']:.0f}, "
            f"{len(r['violations'])} violation(s)"
        )
        for v in r["violations"]:
            print(f"    [{v['severity']}] {v['kind']} {v['op']}: {v['detail']}")
    return {"executables": reports, "ok": ok}


# ---------------------------------------------------------------------------
# --selftest: the gate must trip on one injected violation of each class
# ---------------------------------------------------------------------------

#: lint fixture — one violation per AST rule class.  Deprecated-pattern
#: rules are covered separately (their trigger strings must not appear
#: here or this file itself would trip the gate).
_LINT_FIXTURE = """\
import jax
import jax.numpy as jnp
import numpy as np


class HostMirrorRace:
    def build(self):
        self.mirror = np.zeros(8)
        view = jnp.asarray(self.mirror)          # zero-copy alias
        return view

    def poke(self):
        self.mirror[0] = 1.0                     # ...of a mutated buffer


def decode_step(arr):
    return np.asarray(arr)                       # blocking fetch in hot path


step = jax.jit(lambda p: p, donate_argnums=(0,))  # donation, no out_shardings
"""

_MISSED_DONATION_HLO = """\
HloModule injected_missed

ENTRY %main (p0: f32[64], p1: f32[8]) -> (f32[64], f32[8]) {
  %p0 = f32[64]{0} parameter(0), metadata={op_name="caches[0]"}
  %p1 = f32[8]{0} parameter(1), metadata={op_name="state[0]"}
  ROOT %t = (f32[64]{0}, f32[8]{0}) tuple(%p0, %p1)
}
"""

_FORBIDDEN_DONATION_HLO = """\
HloModule injected_forbidden, input_output_alias={ {0}: (0, {}, may-alias) }

ENTRY %main (p0: f32[64]) -> (f32[64]) {
  %p0 = f32[64]{0} parameter(0), metadata={op_name="caches[0]"}
  ROOT %t = (f32[64]{0}) tuple(%p0)
}
"""

_STRAY_TRANSFER_HLO = """\
HloModule injected_stray

ENTRY %main (p0: f32[1024]) -> f32[1024] {
  %p0 = f32[1024]{0} parameter(0), metadata={op_name="caches[0]"}
  %cs = (f32[1024]{0:S(5)}, f32[1024]{0}, u32[]) copy-start(%p0)
  ROOT %cd = f32[1024]{0:S(5)} copy-done(%cs)
}
"""


def run_selftest() -> dict:
    from repro.analysis import lint
    from repro.analysis.hlo_audit import (
        ExpectedMovement,
        RoleExpectation,
        audit_hlo_text,
    )

    results: dict[str, bool] = {}

    # 1. the serve/ hot-path rule needs a serve-relative path; the other
    #    AST rules fire anywhere
    found = {
        v.rule
        for v in lint.lint_source(
            _LINT_FIXTURE, "src/repro/serve/_injected_fixture.py"
        )
    }
    for rule in (
        "mutated-host-mirror-alias",
        "blocking-transfer-in-hot-path",
        "donate-without-out-shardings",
    ):
        results[f"lint:{rule}"] = rule in found
    # 2. a pragma on the offending line must suppress it
    pragma_src = _LINT_FIXTURE.replace(
        "donate_argnums=(0,))",
        "donate_argnums=(0,))  # repro: lint-disable=donate-without-out-shardings",
    )
    results["lint:pragma-suppresses"] = (
        "donate-without-out-shardings"
        not in {v.rule for v in lint.lint_source(pragma_src, "x.py")}
    )
    # 3. migrated deprecation rules still fire (string assembled so this
    #    file does not trip its own gate)
    dep_src = "x = " + "POLI" + "CIES" + "['kv_host']\n"
    results["lint:deprecated-pattern"] = "deprecated-policies" in {
        v.rule for v in lint.lint_source("x = POLI" + "CIES['kv_host']\n", "y.py")
    } and bool(dep_src)
    # 4. the injected-fault-raise gate: fires outside the harness module
    #    (string assembled so this file does not trip its own gate),
    #    stays quiet inside it, and its allowlist stays scoped to
    #    core/faults.py alone — the harness must not leak into
    #    production control flow through a quietly widened allowlist
    fault_src = "raise " + "TierLossError" + "('peer_hbm')\n"
    results["lint:injected-fault-raise"] = "injected-fault-raise" in {
        v.rule for v in lint.lint_source(fault_src, "src/repro/serve/x.py")
    }
    results["lint:injected-fault-allow-in-harness"] = (
        "injected-fault-raise"
        not in {
            v.rule
            for v in lint.lint_source(fault_src, "src/repro/core/faults.py")
        }
    )
    results["lint:injected-fault-allowlist-scoped"] = (
        lint.get_rule("injected-fault-raise").allow
        == frozenset({"src/repro/core/faults.py"})
    )
    # 5. the cross-pool-device-put gate: fires in serve modules, stays
    #    quiet at the sanctioned crossing site (handoff.py owns the
    #    bridge mesh), and does not reach outside src/repro/serve/
    put_src = "rows = jax.device_put(rows, sharding)\n"
    results["lint:cross-pool-device-put"] = "cross-pool-device-put" in {
        v.rule
        for v in lint.lint_source(put_src, "src/repro/serve/disagg.py")
    }
    results["lint:cross-pool-allow-in-handoff"] = (
        "cross-pool-device-put"
        not in {
            v.rule
            for v in lint.lint_source(put_src, "src/repro/serve/handoff.py")
        }
    )
    results["lint:cross-pool-scoped-to-serve"] = (
        "cross-pool-device-put"
        not in {
            v.rule for v in lint.lint_source(put_src, "src/repro/api.py")
        }
    )

    kv_must_donate = ExpectedMovement(
        roles=(RoleExpectation("kv_cache", "caches", donate=True),),
        label="selftest",
    )
    kv_must_not = ExpectedMovement(
        roles=(RoleExpectation("kv_cache", "caches", donate=False),),
        label="selftest",
    )
    rep = audit_hlo_text(_MISSED_DONATION_HLO, kv_must_donate)
    results["hlo:missed-donation"] = any(
        v.kind == "missed-donation" for v in rep.violations
    )
    rep = audit_hlo_text(_FORBIDDEN_DONATION_HLO, kv_must_not)
    results["hlo:forbidden-donation"] = any(
        v.kind == "forbidden-donation" for v in rep.violations
    )
    rep = audit_hlo_text(
        _STRAY_TRANSFER_HLO,
        ExpectedMovement(
            roles=(RoleExpectation("kv_cache", "caches", donate=False),),
            host_bytes_allowed=0.0,
            label="selftest",
        ),
    )
    results["hlo:stray-host-transfer"] = any(
        v.kind == "stray-host-transfer" for v in rep.violations
    )
    # and the clean case must stay clean
    rep = audit_hlo_text(_MISSED_DONATION_HLO, kv_must_not)
    results["hlo:clean-passes"] = rep.ok

    for name, ok in sorted(results.items()):
        print(f"  {'PASS' if ok else 'FAIL'} {name}")
    return {"checks": results, "ok": all(results.values())}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--lint", action="store_true")
    ap.add_argument("--hlo-audit", action="store_true")
    ap.add_argument("--selftest", action="store_true")
    ap.add_argument("--out", type=pathlib.Path, default=None,
                    help="write audit_report.json here")
    args = ap.parse_args(argv)
    if not (args.lint or args.hlo_audit or args.selftest):
        args.lint = args.hlo_audit = args.selftest = True

    report: dict = {}
    ok = True
    if args.lint:
        print("== lint ==")
        report["lint"] = run_lint()
        ok &= report["lint"]["ok"]
    if args.selftest:
        print("== selftest (injected violations must be caught) ==")
        report["selftest"] = run_selftest()
        ok &= report["selftest"]["ok"]
    if args.hlo_audit:
        print("== hlo audit (smoke-config serve executor) ==")
        report["hlo_audit"] = run_hlo_audit()
        ok &= report["hlo_audit"]["ok"]

    report["ok"] = ok
    if args.out is not None:
        args.out.write_text(json.dumps(report, indent=2, sort_keys=True))
        print(f"wrote {args.out}")
    print("audit", "OK" if ok else "FAILED")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
