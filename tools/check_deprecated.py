#!/usr/bin/env python
"""Deprecation hygiene check: no in-repo caller uses the deprecated
placement paths or the retired monolithic serve-engine surface.

The compositional placement API (ISSUE 5) deprecated three spellings in
favor of ``repro.api`` / the policy registry:

* ``POLICIES``      -> ``registered_policies()`` / ``get_policy()`` /
                       ``parse_policy()``
* ``policy_specs``  -> ``Runtime.specs`` / ``Runtime.realize``
* ``put_like``      -> ``Runtime.realize``

The serve-engine split (ISSUE 6) retired the monolithic engine surface:

* ``repro.serve.engine`` imports -> the ``repro.serve`` package
  (``engine`` now holds only the jitted ``Executor``; ``Request`` /
  ``ServeConfig`` / ``Server`` live in the scheduler layer)
* ``.stats[...]`` dict access    -> the ``Server.stats()`` method

External code keeps working through PEP 562 shims (one
``DeprecationWarning`` per process) where applicable, but nothing inside
this repo may use these spellings: this script greps every tracked
``*.py`` under ``src/``, ``tests/``, ``examples/``, ``benchmarks/``,
``launch/`` and ``tools/`` and exits 1 listing any offender.  The
defining modules (where the shim and the private implementation live)
and the facade are allowlisted.

Run from the repo root:  ``python tools/check_deprecated.py``
(CI runs it on every leg).
"""

from __future__ import annotations

import pathlib
import re
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent

#: deprecated public names.  \b-delimited so attribute access
#: (``sharding.policy_specs``) IS matched — that path hits the shim at
#: runtime too — while the private implementations (``_put_like``,
#: ``_policy_specs``, ``_POLICIES_VIEW``) are not (no word boundary
#: after a leading underscore).
PATTERNS = {
    "POLICIES": re.compile(r"\bPOLICIES\b"),
    "policy_specs": re.compile(r"\bpolicy_specs\b"),
    "put_like": re.compile(r"\bput_like\b"),
    # the monolithic engine surface: import the repro.serve package, not
    # the engine module (which now holds only the Executor).  Matches
    # imports and attribute access, not the logger-name string.
    "repro.serve.engine": re.compile(
        r"(from\s+repro\.serve\.engine\s+import"
        r"|import\s+repro\.serve\.engine"
        r"|\brepro\.serve\.engine\.)"
    ),
    # Server.stats is a method now; dict-style access marks code still
    # written against the old stats attribute
    ".stats[": re.compile(r"\.stats\["),
    # The calibrated hardware model (ISSUE 7) retired direct use of the
    # spec-sheet singleton: pricing must flow through the Runtime facade
    # or get_active_system() so a --calibration run re-prices everything.
    # repro.api re-exports the baseline as SPEC_SYSTEM for explicit
    # spec-vs-calibrated comparisons.
    "DEFAULT_SYSTEM": re.compile(r"\bDEFAULT_SYSTEM\b"),
}

#: modules that define/shim the deprecated names or implement the facade
ALLOWLIST = {
    "src/repro/core/placement.py",
    "src/repro/core/__init__.py",
    # hardware.py defines DEFAULT_SYSTEM; api.py is its one sanctioned
    # consumer (the SPEC_SYSTEM re-export for spec-vs-calibrated reports)
    "src/repro/core/hardware.py",
    "src/repro/models/sharding.py",
    "src/repro/models/__init__.py",
    "src/repro/api.py",
    "tools/check_deprecated.py",
    # the deprecation tests exercise the shims on purpose
    "tests/test_placement_api.py",
    # the serve package itself may reference its own engine module
    "src/repro/serve/__init__.py",
    "src/repro/serve/engine.py",
    "src/repro/serve/scheduler.py",
    "src/repro/serve/sampling.py",
    "src/repro/serve/state.py",
}

SCAN_DIRS = ("src", "tests", "examples", "benchmarks", "tools")


def main() -> int:
    offenders: list[str] = []
    for top in SCAN_DIRS:
        for path in sorted((REPO / top).rglob("*.py")):
            rel = path.relative_to(REPO).as_posix()
            if rel in ALLOWLIST:
                continue
            for lineno, line in enumerate(
                path.read_text().splitlines(), start=1
            ):
                stripped = line.split("#", 1)[0]
                for name, pat in PATTERNS.items():
                    if pat.search(stripped):
                        offenders.append(f"{rel}:{lineno}: {name}: {line.strip()}")
    if offenders:
        print(
            "deprecated placement paths used in-repo (use repro.api / the "
            "policy registry instead):"
        )
        print("\n".join(f"  {o}" for o in offenders))
        return 1
    print("deprecation hygiene OK: no in-repo use of "
          + "/".join(PATTERNS))
    return 0


if __name__ == "__main__":
    sys.exit(main())
