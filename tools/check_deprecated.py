#!/usr/bin/env python
"""RETIRED — the deprecation patterns moved into the lint framework.

This script's checks now live in :mod:`repro.analysis.lint` as registered
``deprecated-*`` rules (with per-rule allowlists and ``# repro:
lint-disable=<rule>`` pragmas), run by ``tools/audit.py`` alongside the
aliasing-discipline rules and the compiled-HLO transfer audit.

Run instead:  ``PYTHONPATH=src python tools/audit.py --lint``
"""

from __future__ import annotations

import sys


def main() -> int:
    print(__doc__.strip(), file=sys.stderr)
    return 2


if __name__ == "__main__":
    sys.exit(main())
