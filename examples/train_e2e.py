"""End-to-end training driver: ~100M-parameter LM, few hundred steps.

    PYTHONPATH=src python examples/train_e2e.py            # ~100M, 300 steps
    PYTHONPATH=src python examples/train_e2e.py --tiny     # CI-speed variant

Exercises the full production path on one host: mesh, FSDP+TP shardings,
remat, prefetching data pipeline, fault-tolerant supervisor with async
checkpoints and straggler monitoring, checkpoint-resume at the end.
"""

import argparse
import logging
import tempfile

import jax
import jax.numpy as jnp

from repro.checkpoint import Checkpointer
from repro.configs import ArchConfig, AttentionSpec
from repro.data import DataConfig, Prefetcher, SyntheticLM
from repro.launch.mesh import make_mesh_for
from repro.models.model_zoo import ModelBundle
from repro.optim import AdamWConfig
from repro.runtime import Supervisor, SupervisorConfig
from repro.train import TrainConfig, init_train_state, make_train_step

log = logging.getLogger("train_e2e")


def config_100m() -> ArchConfig:
    """~100M decoder-only LM (llama-style family)."""
    return ArchConfig(
        name="repro-100m",
        family="dense",
        n_layers=12,
        d_model=768,
        d_ff=2048,
        vocab=32_000,
        layer_pattern="F",
        norm="rmsnorm",
        attention=AttentionSpec(n_heads=12, n_kv_heads=4, d_head=64),
        act="silu",
        dtype="float32",
    )


def config_tiny() -> ArchConfig:
    return ArchConfig(
        name="repro-tiny",
        family="dense",
        n_layers=2,
        d_model=64,
        d_ff=128,
        vocab=512,
        layer_pattern="F",
        norm="rmsnorm",
        attention=AttentionSpec(n_heads=4, n_kv_heads=2, d_head=16),
        dtype="float32",
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--seq", type=int, default=None)
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO, format="%(message)s")

    cfg = config_tiny() if args.tiny else config_100m()
    steps = args.steps or (30 if args.tiny else 300)
    batch = args.batch or (8 if args.tiny else 16)
    seq = args.seq or (32 if args.tiny else 256)

    bundle = ModelBundle(cfg)
    mesh = make_mesh_for((1,), ("data",))
    tcfg = TrainConfig(
        remat="none" if args.tiny else "full",
        optimizer=AdamWConfig(lr=1e-3, warmup_steps=min(50, steps // 5 + 1),
                              weight_decay=0.01),
    )
    params, opt, ef = init_train_state(bundle, mesh, jax.random.PRNGKey(0), tcfg)
    n = sum(x.size for x in jax.tree.leaves(params))
    log.info("%s: %.1fM params, %d steps, batch %d x seq %d",
             cfg.name, n / 1e6, steps, batch, seq)

    step_fn = jax.jit(make_train_step(bundle, mesh, tcfg),
                      donate_argnums=(0, 1))  # repro: lint-disable=donate-without-out-shardings
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=seq,
                                  global_batch=batch, structure=0.9))
    it = Prefetcher(data)

    ckpt_dir = tempfile.mkdtemp(prefix="repro_e2e_")
    sup = Supervisor(Checkpointer(ckpt_dir),
                     SupervisorConfig(checkpoint_every=max(50, steps // 4)))

    losses = []

    def one_step(state, batch_np):
        b = {k: jnp.asarray(v) for k, v in batch_np.items()}
        p, o, e, m = step_fn(state["p"], state["o"], state["e"], b)
        losses.append(float(m["loss"]))
        if len(losses) % 25 == 0:
            log.info("step %4d  loss %.4f", len(losses), losses[-1])
        return {"p": p, "o": o, "e": e}, m

    state = {"p": params, "o": opt, "e": ef}
    state, done = sup.run(state, one_step, it, steps,
                          extra_state=lambda: {"data": data.state()})
    it.close()
    log.info("finished %d steps: loss %.4f -> %.4f | straggler stats: %s",
             done, losses[0], losses[-1], sup.monitor.summary())
    assert losses[-1] < losses[0], "loss did not decrease"
    log.info("checkpoints in %s: OK", ckpt_dir)


if __name__ == "__main__":
    main()
