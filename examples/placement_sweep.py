"""Placement sweep: the paper's §IV study as a runnable decision procedure.

    PYTHONPATH=src python examples/placement_sweep.py [--arch gemma3-27b]

Two parts, mirroring the paper's predicted-vs-measured method:

1. **Predicted** (Figs. 15-17 table, generated): for the full-size
   architecture at ``--chips`` chips, the datapath planner's step-time
   prediction + memory-pool fit for *every* placement policy, in both the
   training and decode regimes, and which policy the launcher would pick.

2. **Predicted vs measured**: the same-family smoke config is actually run
   on this host — one jitted decode step per policy, with params/KV placed
   under the policy's (backend-resolved) memory kinds and, for peer/remote
   policies, sharded across a **donor mesh axis** — next to the planner's
   prediction for *this* machine's workload shape.  The final column is
   the paper's headline metric, measured/predicted.  On a CPU container
   every tier resolves to the same physical memory, so measured times
   coincide by construction; a TPU backend separates the *host* tiers for
   real and puts peer/remote bytes an ICI/DCN hop away.  Peer/remote rows
   need >= 2 devices (run under
   ``XLA_FLAGS=--xla_force_host_platform_device_count=4`` on CPU to
   exercise them); with a single device they are starred: no donor mesh
   axis exists, the engine would refuse to realize them, and only the
   prediction is reported.

``--analytic`` prints the predicted tables only (the CI smoke mode).
``--calibration calibration.json`` activates a measurement-calibrated
hardware model: every prediction is then made under the calibrated
constants and the measured column reports its achieved-over-bound ratio
against **both** the spec-sheet and calibrated predictions — how much
calibration moved each policy's number.
"""

import argparse
import os
import time

from repro.api import SPEC_SYSTEM
from repro.configs import SHAPES, ShapeSpec, get_config, list_archs, smoke_config
from repro.core.hardware import get_active_system
from repro.core.placement import (
    Role,
    TIER_DONOR_AXIS,
    host_available,
    registered_policies,
)
from repro.core.planner import plan, predict
from repro.models.model_zoo import ModelBundle


def _calibrated() -> bool:
    return get_active_system() is not SPEC_SYSTEM


def _mesh_axes(chips: int, data_axis: int, pod_axis: int) -> tuple[int, int]:
    """Clamp the requested axis sizes to what ``chips`` can host."""
    if data_axis * pod_axis > chips:
        pod_axis = 1
        data_axis = min(data_axis, chips)
    return data_axis, pod_axis


def predicted_tables(arch: str, chips: int, data_axis: int,
                     pod_axis: int) -> None:
    bundle = ModelBundle(get_config(arch))
    cfg = bundle.cfg
    data_axis, pod_axis = _mesh_axes(chips, data_axis, pod_axis)

    print(f"=== {cfg.name}: {cfg.num_params()/1e9:.1f}B params, "
          f"{chips} chips (data axis {data_axis}, pod axis {pod_axis}) ===\n")

    def _table(prof):
        # plan() prices under the active system; with a calibration
        # active, each row also shows the spec-sheet step time so the
        # table says how much calibration moved every prediction.
        best, preds = plan(prof)
        spec = {}
        if _calibrated():
            _, sp = plan(prof, system=SPEC_SYSTEM)
            spec = {p.policy: p for p in sp}
        for p in preds:
            mark = " <== planner pick" if p.policy == best.policy else ""
            extra = (f" [spec: {spec[p.policy].step_s*1e3:.3f}ms]"
                     if p.policy in spec else "")
            print("  " + p.explain() + extra + mark)

    print("-- training (train_4k) --")
    _table(bundle.train_workload(
        SHAPES["train_4k"],
        num_chips=chips,
        data_axis_size=data_axis,
        pod_axis_size=pod_axis,
    ))

    print("\n-- decoding (decode_32k) --")
    _table(bundle.decode_workload(SHAPES["decode_32k"], num_chips=chips))


def _mesh_for_policy(policy):
    """Mesh that realizes ``policy``: a plain 1-device mesh for local
    tiers, a 2-slice donor mesh (ICI or DCN axis per the tier) for
    peer/remote tiers — or None when this host lacks the devices."""
    import jax

    from repro.launch.mesh import make_donor_mesh, make_mesh_for

    donor_axes = {
        TIER_DONOR_AXIS[t] for t in policy.tiers() if t in TIER_DONOR_AXIS
    }
    if not donor_axes:
        return make_mesh_for((1,), ("data",))
    if jax.device_count() < 2 or len(donor_axes) > 1:
        return None
    return make_donor_mesh(
        (1,), ("data",), 2, remote=donor_axes == {"donor_pod"}
    )


def _measure_decode_ms(bundle, policy, slots: int, max_len: int,
                       iters: int) -> float | None:
    """Wall-clock of one jitted decode step under ``policy`` placements,
    realized on a donor mesh for peer/remote tiers (None when this host
    cannot realize the policy)."""
    import jax
    import jax.numpy as jnp

    from repro.api import Runtime

    mesh = _mesh_for_policy(policy)
    if mesh is None:
        return None
    rt = Runtime(bundle, mesh, policy)
    params = bundle.init_params(jax.random.PRNGKey(0), "float32")
    params = rt.realize(params, Role.PARAMS)
    cache_defs = bundle.cache_defs(slots, max_len)
    caches = rt.realize(bundle.init_cache(slots, max_len),
                        Role.KV_CACHE, cache_defs)
    cache_specs = rt.specs(Role.KV_CACHE, cache_defs)

    step = jax.jit(
        lambda p, b, c: bundle.decode_step(p, b, c),
        out_shardings=(None, cache_specs),
    )
    batch = {
        "tokens": jnp.ones((slots, 1), jnp.int32),
        "lengths": jnp.full((slots,), 4, jnp.int32),
    }
    logits, caches = step(params, batch, caches)  # compile
    jax.block_until_ready(logits)
    t0 = time.perf_counter()
    for _ in range(iters):
        logits, caches = step(params, batch, caches)
    jax.block_until_ready(logits)
    return (time.perf_counter() - t0) / iters * 1e3


def predicted_vs_measured(arch: str, slots: int, max_len: int,
                          iters: int) -> None:
    import jax

    bundle = ModelBundle(smoke_config(arch))
    cfg = bundle.cfg

    prof = bundle.decode_workload(
        ShapeSpec("local", max_len, slots, "decode"), num_chips=1
    )
    cal = _calibrated()
    print(f"\n=== predicted vs measured: {cfg.name} decode on this host "
          f"({slots} slots x {max_len} ctx, host_available="
          f"{host_available()}, devices={jax.device_count()}, "
          f"calibration={'active' if cal else 'none (spec sheet)'}) ===")
    if cal:
        print(f"{'policy':<20} {'fits':<5} {'pred spec ms':>12} "
              f"{'pred cal ms':>12} {'measured ms':>12} "
              f"{'meas/spec':>10} {'meas/cal':>9}")
    else:
        print(f"{'policy':<20} {'fits':<5} {'predicted ms':>12} "
              f"{'measured ms':>12} {'meas/pred':>10}")
    starred = False

    def _ratio(meas_ms, pred_s):
        return meas_ms / (pred_s * 1e3) if pred_s else float("inf")

    # the registry, not a hand-written list: custom register_policy()'d
    # policies show up in the sweep automatically
    for policy in registered_policies().values():
        pred = predict(prof, policy)   # under the active (cal'd) system
        spec_pred = predict(prof, policy, SPEC_SYSTEM) if cal else pred
        meas = _measure_decode_ms(bundle, policy, slots, max_len, iters)
        if meas is None:
            starred = True
            if cal:
                print(f"{policy.name + '*':<20} {str(pred.fits):<5} "
                      f"{spec_pred.step_s*1e3:>12.4f} "
                      f"{pred.step_s*1e3:>12.4f} {'-':>12} {'-':>10} "
                      f"{'-':>9}")
            else:
                print(f"{policy.name + '*':<20} {str(pred.fits):<5} "
                      f"{pred.step_s*1e3:>12.4f} {'-':>12} {'-':>10}")
            continue
        if cal:
            print(f"{policy.name:<20} {str(pred.fits):<5} "
                  f"{spec_pred.step_s*1e3:>12.4f} {pred.step_s*1e3:>12.4f} "
                  f"{meas:>12.4f} {_ratio(meas, spec_pred.step_s):>10.1f} "
                  f"{_ratio(meas, pred.step_s):>9.1f}")
        else:
            print(f"{policy.name:<20} {str(pred.fits):<5} "
                  f"{pred.step_s*1e3:>12.4f} {meas:>12.4f} "
                  f"{_ratio(meas, pred.step_s):>10.1f}")
    if starred:
        print("* not measurable here: needs a donor mesh axis (>=2 devices; "
              "set XLA_FLAGS=--xla_force_host_platform_device_count=4)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-27b", choices=list_archs())
    ap.add_argument("--chips", type=int, default=256)
    ap.add_argument("--data-axis", type=int, default=16,
                    help="data-parallel (ICI) axis size for the train table")
    ap.add_argument("--pod-axis", type=int, default=2,
                    help="pod (DCN) axis size for the train table")
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--iters", type=int, default=8)
    ap.add_argument("--no-measure", "--analytic", dest="no_measure",
                    action="store_true",
                    help="predicted tables only (pure analysis; the CI "
                         "smoke mode)")
    ap.add_argument("--calibration", default=None, metavar="PATH",
                    help="activate a calibration.json (tools/calibrate.py) "
                         "so predictions use measured constants and the "
                         "table reports meas/spec AND meas/cal ratios; "
                         "defaults to ./calibration.json when it exists")
    args = ap.parse_args()

    cal_path = args.calibration
    if cal_path is None and os.path.exists("calibration.json"):
        cal_path = "calibration.json"
    if cal_path:
        from repro.core.calibration import load_or_calibrate

        load_or_calibrate(cal_path, activate=True)
        print(f"(calibration active: {cal_path})\n")

    predicted_tables(args.arch, args.chips, args.data_axis, args.pod_axis)
    if not args.no_measure:
        predicted_vs_measured(args.arch, args.slots, args.max_len, args.iters)


if __name__ == "__main__":
    main()
