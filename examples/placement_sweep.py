"""Placement sweep: the paper's §IV study as a runnable decision procedure.

    PYTHONPATH=src python examples/placement_sweep.py [--arch gemma3-27b]

For a full-size architecture, evaluates every placement policy with the
datapath planner (predicted step time + HBM fit at 256 chips), prints the
Fig. 17-style table, and shows which policy the launcher would pick.
"""

import argparse

from repro.configs import SHAPES, get_config, list_archs
from repro.core.planner import decode_profile, plan, train_profile
from repro.models.model_zoo import ModelBundle


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-27b", choices=list_archs())
    ap.add_argument("--chips", type=int, default=256)
    args = ap.parse_args()

    bundle = ModelBundle(get_config(args.arch))
    cfg = bundle.cfg

    print(f"=== {cfg.name}: {cfg.num_params()/1e9:.1f}B params, "
          f"{args.chips} chips ===\n")

    print("-- training (train_4k) --")
    shape = SHAPES["train_4k"]
    prof = train_profile(
        name=cfg.name,
        param_bytes=cfg.num_params() * 2,
        step_flops=bundle.model_flops(shape),
        activation_bytes=2.0 * shape.global_batch * shape.seq_len
        * cfg.d_model * cfg.n_layers,
        num_chips=args.chips,
    )
    best, preds = plan(prof)
    for p in preds:
        mark = " <== planner pick" if p.policy == best.policy else ""
        print("  " + p.explain() + mark)

    print("\n-- decoding (decode_32k) --")
    shape = SHAPES["decode_32k"]
    prof = decode_profile(
        name=cfg.name,
        param_bytes=cfg.num_params() * 2,
        kv_bytes=bundle.cache_bytes(shape),
        step_flops=bundle.model_flops(shape),
        num_chips=args.chips,
    )
    best, preds = plan(prof)
    for p in preds:
        mark = " <== planner pick" if p.policy == best.policy else ""
        print("  " + p.explain() + mark)


if __name__ == "__main__":
    main()
