"""Quickstart: train a reduced-config LM for a few steps on CPU.

    PYTHONPATH=src python examples/quickstart.py [--arch granite-8b]

Touches the whole public API surface in ~40 lines: config registry, model
bundle, mesh, placement-aware train state, jit'd train step, data pipeline.
"""

import argparse

import jax
import jax.numpy as jnp

from repro.data import DataConfig, SyntheticLM
from repro.launch.mesh import make_mesh_for
from repro.models import get_smoke_bundle
from repro.optim import AdamWConfig
from repro.train import TrainConfig, init_train_state, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--steps", type=int, default=30)
    args = ap.parse_args()

    bundle = get_smoke_bundle(args.arch)
    mesh = make_mesh_for((1,), ("data",))
    tcfg = TrainConfig(
        remat="none",
        optimizer=AdamWConfig(lr=3e-3, warmup_steps=5, weight_decay=0.0),
    )
    params, opt_state, ef = init_train_state(
        bundle, mesh, jax.random.PRNGKey(0), tcfg
    )
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"{args.arch} (reduced): {n_params/1e6:.2f}M params")

    step = jax.jit(make_train_step(bundle, mesh, tcfg),
                   donate_argnums=(0, 1))  # repro: lint-disable=donate-without-out-shardings
    data = SyntheticLM(
        DataConfig(vocab=bundle.cfg.vocab, seq_len=32, global_batch=8,
                   structure=1.0)
    )
    for i, batch in zip(range(args.steps), data):
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt_state, ef, metrics = step(params, opt_state, ef, batch)
        if i % 5 == 0 or i == args.steps - 1:
            print(f"step {i:3d}  loss {float(metrics['loss']):.4f}  "
                  f"grad_norm {float(metrics['grad_norm']):.3f}")


if __name__ == "__main__":
    main()
