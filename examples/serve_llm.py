"""Batched serving example: continuous batching, sampling, streaming.

    PYTHONPATH=src python examples/serve_llm.py [--policy kv_host]

Serves a stream of synthetic requests through the layered serve stack —
batched admission into the chunked prefill path, donated-cache decode
steps with per-request sampling computed in-jit — and reports prefill vs
decode tokens/s per placement policy: the paper's Fig. 17 experiment as
a runnable service loop.  Requests mix greedy decode with seeded
temperature/top-k/top-p sampling, tokens stream through ``on_token``
callbacks as they decode, and ``--asyncio`` drives the same workload
through the async :class:`~repro.serve.Scheduler` front end
(``await submit()`` / ``async for tok in stream()``).
"""

import argparse
import asyncio
import time

import jax
import numpy as np

from repro.core.placement import registered_policies
from repro.models import get_smoke_bundle
from repro.serve import (
    Request,
    SamplingParams,
    Scheduler,
    ServeConfig,
    Server,
)


def make_sampling(i: int) -> SamplingParams:
    """Alternate greedy and seeded nucleus sampling across requests."""
    if i % 2 == 0:
        return SamplingParams()  # temperature=0 -> greedy
    return SamplingParams(temperature=0.8, top_k=40, top_p=0.95, seed=i)


def run_sync(bundle, params, args, pname, rng) -> None:
    server = Server(
        bundle,
        ServeConfig(
            batch_slots=3,
            max_len=128,
            prefill_chunk=args.prefill_chunk,
            policy=pname,   # ServeConfig accepts any policy spelling
        ),
        params,
    )
    streamed: dict[int, int] = {}

    def on_token(req: Request, tok: int) -> None:
        # fires the tick each token is decoded; req.done marks the last
        streamed[req.rid] = streamed.get(req.rid, 0) + 1

    reqs = [
        Request(
            rid=i,
            prompt=rng.integers(
                0, bundle.cfg.vocab, args.prompt_len
            ).astype(np.int32),
            max_new_tokens=args.max_new,
            sampling=make_sampling(i),
            on_token=on_token,
        )
        for i in range(args.requests)
    ]
    server.add_requests(reqs)          # batched admission
    t0 = time.perf_counter()
    server.run_until_done()
    dt = time.perf_counter() - t0
    total = sum(len(r.out_tokens) for r in reqs)
    assert streamed == {r.rid: len(r.out_tokens) for r in reqs}
    tp = server.throughput()
    print(
        f"[{pname}] {args.requests} requests, {total} tokens in "
        f"{dt:.2f}s -> {total/dt:.1f} tok/s overall | prefill "
        f"{tp['prefill_tps']:.1f} tok/s ({tp['prefill_tokens']} tok) | "
        f"decode {tp['decode_tps']:.1f} tok/s ({tp['decode_tokens']} tok)"
    )
    for r in reqs[:2]:
        mode = "greedy" if r.sampling.temperature == 0 else (
            f"T={r.sampling.temperature} top_k={r.sampling.top_k} "
            f"top_p={r.sampling.top_p} seed={r.sampling.seed}"
        )
        print(f"  req {r.rid} ({mode}): prompt {r.prompt[:6]}... "
              f"-> {r.out_tokens}")


async def run_async(bundle, params, args, pname, rng) -> None:
    """The same workload through the asyncio front end: submissions
    absorb backpressure, tokens stream as they decode."""
    server = Server(
        bundle,
        ServeConfig(batch_slots=3, max_len=128,
                    prefill_chunk=args.prefill_chunk, policy=pname,
                    max_queue=max(args.requests // 2, 1)),
        params,
    )
    sched = Scheduler(server)

    async def client(i: int) -> list[int]:
        req = await sched.submit(   # awaits queue space when full
            rng.integers(0, bundle.cfg.vocab, args.prompt_len)
            .astype(np.int32),
            max_new_tokens=args.max_new,
            sampling=make_sampling(i),
        )
        return [tok async for tok in sched.stream(req)]

    async def clients():
        outs = await asyncio.gather(
            *(client(i) for i in range(args.requests)))
        sched.close()
        return outs

    _, outs = await asyncio.gather(sched.run(), clients())
    total = sum(len(o) for o in outs)
    print(f"[{pname}] asyncio front end streamed {total} tokens across "
          f"{len(outs)} concurrent clients")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--prefill-chunk", type=int, default=8)
    ap.add_argument(
        "--policy", default=None,
        help="a registered policy name "
             f"({', '.join(registered_policies())}), the "
             "role=tier[:strategy][,...] grammar, or policy JSON",
    )
    ap.add_argument("--asyncio", action="store_true",
                    help="also drive the workload through the async "
                         "Scheduler front end")
    args = ap.parse_args()

    bundle = get_smoke_bundle(args.arch)
    params = bundle.init_params(jax.random.PRNGKey(0), "float32")
    rng = np.random.default_rng(0)
    policies = [args.policy] if args.policy else ["hbm_resident"]

    for pname in policies:
        run_sync(bundle, params, args, pname, rng)
        if args.asyncio:
            asyncio.run(run_async(bundle, params, args, pname, rng))


if __name__ == "__main__":
    main()
