"""Batched serving example: continuous batching with placement policies.

    PYTHONPATH=src python examples/serve_llm.py [--policy kv_host]

Serves a stream of synthetic requests through the continuous-batching
engine — batched admission into the chunked prefill path, donated-cache
decode steps — and reports prefill vs decode tokens/s per placement
policy: the paper's Fig. 17 experiment as a runnable service loop.
"""

import argparse
import time

import jax
import numpy as np

from repro.core.placement import registered_policies
from repro.models import get_smoke_bundle
from repro.serve import Request, ServeConfig, Server


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--prefill-chunk", type=int, default=8)
    ap.add_argument(
        "--policy", default=None,
        help="a registered policy name "
             f"({', '.join(registered_policies())}), the "
             "role=tier[:strategy][,...] grammar, or policy JSON",
    )
    args = ap.parse_args()

    bundle = get_smoke_bundle(args.arch)
    params = bundle.init_params(jax.random.PRNGKey(0), "float32")
    rng = np.random.default_rng(0)
    policies = [args.policy] if args.policy else ["hbm_resident"]

    for pname in policies:
        server = Server(
            bundle,
            ServeConfig(
                batch_slots=3,
                max_len=128,
                prefill_chunk=args.prefill_chunk,
                policy=pname,   # ServeConfig accepts any policy spelling
            ),
            params,
        )
        reqs = [
            Request(
                rid=i,
                prompt=rng.integers(
                    0, bundle.cfg.vocab, args.prompt_len
                ).astype(np.int32),
                max_new_tokens=args.max_new,
            )
            for i in range(args.requests)
        ]
        server.add_requests(reqs)          # batched admission
        t0 = time.perf_counter()
        server.run_until_done()
        dt = time.perf_counter() - t0
        total = sum(len(r.out_tokens) for r in reqs)
        tp = server.throughput()
        print(
            f"[{pname}] {args.requests} requests, {total} tokens in "
            f"{dt:.2f}s -> {total/dt:.1f} tok/s overall | prefill "
            f"{tp['prefill_tps']:.1f} tok/s ({tp['prefill_tokens']} tok) | "
            f"decode {tp['decode_tps']:.1f} tok/s ({tp['decode_tokens']} tok)"
        )
        for r in reqs[:2]:
            print(f"  req {r.rid}: prompt {r.prompt[:6]}... -> {r.out_tokens}")


if __name__ == "__main__":
    main()
